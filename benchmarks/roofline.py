"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape, single-pod 16x16 mesh): the three terms in seconds,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, memory fit, and the per-cell
one-line mitigation note."""
from __future__ import annotations

import json
import pathlib

from repro.launch import hw

NOTES = {
    "t_compute": "compute-bound: raise MXU utilization (larger microbatch, "
    "fuse small ops, avoid replicated attention work)",
    "t_memory": "HBM-bound: cut activation/cache traffic (flash kernels, "
    "bf16 caches, fewer passes)",
    "t_collective": "collective-bound: reshard to cut gathers (inference "
    "weight layout, batch-level FSDP prefetch, overlap)",
}


def load(outdir="results/dryrun"):
    recs = []
    for p in sorted(pathlib.Path(outdir).glob("*.json")):
        r = json.loads(p.read_text())
        recs.append(r)
    return recs


def table(outdir="results/dryrun", mesh="single"):
    rows = []
    for r in load(outdir):
        if r.get("skipped"):
            rows.append({
                "cell": f"{r['arch']}/{r['shape']}",
                "skipped": r["skipped"],
            })
            continue
        if r.get("mesh") != mesh or r.get("tag"):
            continue
        rl = r["roofline"]
        t_tot = max(rl["t_compute"], rl["t_memory"], rl["t_collective"])
        rows.append({
            "cell": f"{r['arch']}/{r['shape']}",
            "t_compute_s": rl["t_compute"],
            "t_memory_s": rl["t_memory"],
            "t_collective_s": rl["t_collective"],
            "dominant": rl["dominant"][2:],
            "model/hlo_flops": rl["useful_flops_ratio"],
            "roofline_frac": rl["t_compute"] / t_tot if t_tot else 0.0,
            "mem_GiB": r["memory"]["peak_est_bytes"] / 2**30,
            "fits": r["memory"]["peak_est_bytes"] <= hw.HBM_PER_CHIP,
            "note": NOTES[rl["dominant"]],
        })
    return rows


def run():
    rows = []
    for t in table():
        if "skipped" in t:
            rows.append((f"roofline_{t['cell']}", 0.0, {"skipped": t["skipped"]}))
            continue
        rows.append((
            f"roofline_{t['cell']}",
            t["t_compute_s"] * 1e6,  # the compute term doubles as us_per_call
            {
                "dom": t["dominant"],
                "frac_of_roofline": round(t["roofline_frac"], 3),
                "useful": round(t["model/hlo_flops"], 3),
                "t_mem_s": round(t["t_memory_s"], 4),
                "t_coll_s": round(t["t_collective_s"], 4),
                "mem_GiB": round(t["mem_GiB"], 2),
                "fits": t["fits"],
            },
        ))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
