"""Benchmark harness: one module per paper table/figure (+ the roofline
report). Prints ``name,us_per_call,derived`` CSV.

  fig1   -- sample-size behaviour, T-TBS vs R-TBS (paper Fig. 1)
  table1 -- kNN accuracy + 10% ES across drift patterns (paper Table 1/Fig.10)
  fig12  -- linear regression MSE + ES, saturated/unsaturated (paper Fig. 12)
  fig13  -- Naive Bayes on the Usenet2-like stream (paper Fig. 13)
  fig789 -- distributed impl comparison + scale-out/up (paper Figs. 7-9)
  manage -- fused/superbatched manage loop + sampler-step criterion
            (writes BENCH_manage_loop.json)
  sampler -- sampler-step throughput sweep, fused vs pre-fused reference
            (writes BENCH_sampler_step.json)
  decay  -- static lambda vs polynomial vs adaptive decay on the Sec. 6.2
            drift scenarios (writes BENCH_decay_sweep.json)
  bank   -- keyed multi-tenant bank step vs naive per-key dispatch at
            growing K (writes BENCH_bank_step.json)
  obs    -- in-scan telemetry on/off overhead on the fused manage loop and
            the K=4096 bank step (writes BENCH_obs_overhead.json)
  roofline -- dry-run roofline table (EXPERIMENTS.md §Roofline)

Select with ``python -m benchmarks.run [names...]`` (default: all).
``--smoke`` / BENCH_SMOKE=1 shrinks the json-emitting suites to CI size.
"""
from __future__ import annotations

import sys
import time

from .common import emit

SUITES = ["fig1", "table1", "fig12", "fig13", "fig789", "manage", "sampler",
          "decay", "bank", "obs", "roofline"]


def main() -> None:
    args = [a for a in sys.argv[1:] if a != "--smoke"] or SUITES
    for name in args:
        t0 = time.time()
        if name == "fig1":
            from . import fig1_sample_size as m
        elif name == "table1":
            from . import table1_knn as m
        elif name == "fig12":
            from . import fig12_linreg as m
        elif name == "fig13":
            from . import fig13_nb as m
        elif name == "fig789":
            from . import fig789_distributed as m
        elif name == "manage":
            from . import manage_loop as m
        elif name == "sampler":
            from . import sampler_step as m
        elif name == "decay":
            from . import decay_sweep as m
        elif name == "bank":
            from . import bank_step as m
        elif name == "obs":
            from . import obs_overhead as m
        elif name == "roofline":
            from . import roofline as m
        else:
            raise SystemExit(f"unknown suite {name}; pick from {SUITES}")
        try:
            rows = m.run()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0.0,ERROR={e!r}", flush=True)
            continue
        emit(rows)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
