"""Decay-schedule sweep: static lambda grid vs polynomial vs closed-loop
adaptive decay on the paper's Sec. 6.2 drift scenarios (DESIGN.md Sec. 12,
EXPERIMENTS.md §Decay-sweep).

The decay rate is the robustness-vs-adaptivity dial of the whole paper
(Sec. 3); this sweep measures where each point of the dial lands when the
kNN-on-GMM experiment (Sec. 6.2) is run under ``single`` (one regime change)
and ``periodic`` (recurring changes) drift:

  * ``static_lamXX`` -- R-TBS with a frozen exponential rate (the grid the
    pre-decay-subsystem repo could express);
  * ``poly_bXX``     -- :func:`repro.decay.polynomial` power-law decay:
    forgetting slows as the stream ages (robust, slow to adapt);
  * ``adaptive``     -- :func:`repro.decay.loss_ratio` driving lambda from
    the prequential miss rate inside the fused loop
    (``make_run_farm(..., controller=...)``).

Every variant runs the SAME fused Monte-Carlo farm (trials x one stream) with
retraining every tick; reported per row: mean prequential miss over the
whole drifted window, mean over the post-shift window (``single`` scenario:
the recovery+steady segment after the change -- the criterion the adaptive
controller is designed to win), 10% expected shortfall (robustness), and the
mean realized sample size. ``us_per_call`` is wall time per trial-tick of
the timed farm dispatch. Emits ``BENCH_decay_sweep.json`` at the repo root
(schema: benchmarks/check_bench.py; CI regenerates at ``--smoke`` size).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import decay as dk
from repro.core.api import make_sampler
from repro.data.streams import GMMStream, mode_schedule
from repro.manage import make_model, make_run_farm, materialize_stream
from repro.models.simple_ml import expected_shortfall

from .common import smoke_mode, write_bench_json

WARM = 30        # pre-drift warm-up ticks
T = 40           # drifted/evaluated ticks
TRIALS = 8
SHIFT_SKIP = 3   # single: ticks after the change nobody can predict

#: (drift kind, GMM frequency ratio, items/tick b, sample bound n).
#: ``single``/``periodic`` are the paper's Sec.-6.2 settings (ratio 5); the
#: ``single_sharp`` variant makes the dial's trade-off binding -- a sharp
#: frequency flip (stale samples costly) with b << n (every fast-flushing
#: static rate runs with a shrunken steady sample) -- which is where the
#: closed-loop controller separates from the whole static grid (the
#: convergence criterion asserted in tests/test_decay.py).
SCENARIOS = {
    "single": ("single", 5.0, 100, 600),
    "periodic": ("periodic", 5.0, 100, 600),
    "single_sharp": ("single", 25.0, 50, 400),
}

LAM_GRID = (0.005, 0.05, 0.2, 0.5)
BETAS = (0.8, 2.0)
ADAPTIVE = dict(lam0=0.05, lam_min=0.005, lam_max=0.5)


def variants(smoke: bool):
    lam_grid = LAM_GRID if not smoke else LAM_GRID[1:2]
    betas = BETAS if not smoke else BETAS[:1]
    out = []
    for lam in lam_grid:
        out.append((f"static_lam{lam:g}", {"lam": lam}, None,
                    {"decay": f"exponential(lam={lam:g})"}))
    for beta in betas:
        out.append((f"poly_b{beta:g}", {"decay": dk.polynomial(beta)}, None,
                    {"decay": f"polynomial(beta={beta:g})"}))
    out.append(("adaptive", {"lam": ADAPTIVE["lam0"]},
                dk.loss_ratio(**ADAPTIVE),
                {"decay": f"loss_ratio({ADAPTIVE})"}))
    return out


def run():
    smoke = smoke_mode()
    warm, T_, trials = (6, 10, 2) if smoke else (WARM, T, TRIALS)

    rows = []
    for scenario, (kind, ratio, B_, N_) in SCENARIOS.items():
        if smoke:
            B_, N_ = 20, 60
        # R-TBS buffer capacity (n + 1) is the knn param store size
        model = make_model("knn", cap=N_ + 1, dim=2, k=7, num_classes=100)
        stream = GMMStream(seed=0, ratio=ratio)

        def mode_of(t, kind=kind, T_=T_):
            if t < warm:
                return 0
            return mode_schedule(kind, t - warm, delta=10, eta=10,
                                 start=0, stop=T_)
        batches, bcounts = materialize_stream(
            stream, warm + T_, batch_size=B_, mode=mode_of,
            fields=("x", "y"),
        )
        for label, hyper, controller, derived in variants(smoke):
            sampler = make_sampler("rtbs", n=N_, **hyper)
            farm = make_run_farm(sampler, model, retrain_every=1,
                                 controller=controller)
            key = jax.random.key(7)
            trace = farm(key, trials, batches, bcounts)  # compile + warm
            jax.block_until_ready(trace["metric"])
            t0 = time.perf_counter()
            trace = farm(jax.random.key(8), trials, batches, bcounts)
            jax.block_until_ready(trace["metric"])
            us = (time.perf_counter() - t0) * 1e6 / (trials * (warm + T_))

            miss = np.asarray(trace["metric"])[:, warm:]       # [trials, T]
            sizes = np.asarray(trace["size"])[:, warm:]
            post = miss[:, SHIFT_SKIP:] if kind == "single" else miss
            d = dict(derived)
            d.update(
                scenario=scenario,
                mean_loss=round(float(miss.mean()), 4),
                post_shift_loss=round(float(post.mean()), 4),
                es10=round(float(np.mean(
                    [expected_shortfall(m, 0.10) for m in miss]
                )), 4),
                avg_sample=round(float(sizes.mean()), 1),
            )
            if controller is not None and "decay" in trace:
                lam_path = -np.log(np.maximum(
                    np.asarray(trace["decay"]), 1e-30))
                d["lam_final"] = round(float(lam_path[:, -1].mean()), 4)
                d["lam_peak"] = round(float(lam_path[:, warm:].max()), 4)
            rows.append((f"decay_sweep_{scenario}_{label}", us, d))
    write_bench_json("decay_sweep", rows)
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
