"""Validate emitted BENCH_*.json files against the shared schema.

Usage: ``python -m benchmarks.check_bench BENCH_manage_loop.json [...]``
(no args: validate every BENCH_*.json at the repo root). Exits non-zero on
the first violation -- the CI bench-smoke job gates on this before uploading
the files as artifacts, so the PR-over-PR perf trajectory stays parseable.
"""
from __future__ import annotations

import json
import pathlib
import sys

from .common import BENCH_SCHEMA_KEYS, PROVENANCE_KEYS, REPO_ROOT

#: per-suite required derived fields on at least one row (the criterion rows)
REQUIRED_ROW_FIELDS = {
    "sampler_step": ("scheme", "cap", "impl", "items_per_s", "steps_per_s"),
    "manage_loop": ("ticks_per_s",),
    "decay_sweep": ("scenario", "decay", "mean_loss", "post_shift_loss",
                    "es10"),
    "bank_step": ("scheme", "K", "impl", "keys_touched", "keys_per_s",
                  "items_per_s"),
    "obs_overhead": ("overhead_pct",),
}


def check_file(path: pathlib.Path) -> list[str]:
    errors = []
    try:
        payload = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unreadable ({e})"]
    for k in BENCH_SCHEMA_KEYS:
        if k not in payload:
            errors.append(f"{path.name}: missing top-level key {k!r}")
    # run provenance (who/what/when produced the numbers) is mandatory
    prov = payload.get("provenance")
    if not isinstance(prov, dict):
        errors.append(f"{path.name}: provenance must be a dict")
    else:
        for k in PROVENANCE_KEYS:
            if k not in prov:
                errors.append(f"{path.name}: provenance missing {k!r}")
    rows = payload.get("rows", [])
    if not isinstance(rows, list) or not rows:
        errors.append(f"{path.name}: rows must be a non-empty list")
        return errors
    for i, row in enumerate(rows):
        if not isinstance(row.get("name"), str):
            errors.append(f"{path.name}: rows[{i}] missing str 'name'")
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or us <= 0:
            errors.append(f"{path.name}: rows[{i}] bad us_per_call {us!r}")
    bench = payload.get("benchmark")
    for field in REQUIRED_ROW_FIELDS.get(bench, ()):
        if not any(field in r for r in rows):
            errors.append(f"{path.name}: no row carries {field!r}")
    # the decay sweep must cover the three schedule families it exists to
    # compare (static exponential grid, polynomial, closed-loop adaptive)
    if bench == "decay_sweep":
        for fam in ("static_lam", "poly_b", "adaptive"):
            if not any(fam in r.get("name", "") for r in rows):
                errors.append(f"{path.name}: no {fam}* rows")
        adaptive = [r for r in rows if "adaptive" in r.get("name", "")]
        if adaptive and not any("lam_final" in r for r in adaptive):
            errors.append(f"{path.name}: adaptive rows lack lam_final")
    # the headline criterion: the fused sampler-step rows must record their
    # speedup against the pre-fused reference
    if bench in ("sampler_step", "manage_loop", "bank_step"):
        fused = [r for r in rows if r.get("impl") == "fused"]
        if fused and not any("speedup_vs_ref" in r for r in fused):
            errors.append(f"{path.name}: fused rows lack speedup_vs_ref")
    return errors


def main() -> None:
    paths = [pathlib.Path(a) for a in sys.argv[1:]]
    if not paths:
        paths = sorted(REPO_ROOT.glob("BENCH_*.json"))
    if not paths:
        raise SystemExit("no BENCH_*.json files found")
    errors = []
    for p in paths:
        errors += check_file(p)
    for e in errors:
        print(f"SCHEMA ERROR: {e}", file=sys.stderr)
    if errors:
        raise SystemExit(1)
    print(f"ok: {', '.join(p.name for p in paths)} valid")


if __name__ == "__main__":
    main()
