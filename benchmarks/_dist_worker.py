"""Subprocess worker for the distributed benchmarks (Figs. 7-9).

Usage: python -m benchmarks._dist_worker <shards> <batch_per_shard> <impl>
Prints: ``<impl>,<us_per_batch>``.

Implementations (paper Fig. 7, Spark design points mapped to the mesh --
DESIGN.md Sec. 3):
  cp_dist  -- co-partitioned reservoir + distributed decisions (D-R-TBS prod)
  cp_cent  -- co-partitioned reservoir + centralized decisions (replicated
              global slot permutation, master-style)
  kv_cj    -- key-value-store reservoir emulation w/ co-located join: insert
              payloads cross the network once (all_gather of half the batch)
  kv_rj    -- key-value emulation w/ repartition join: payloads cross twice
  dttbs    -- D-T-TBS (embarrassingly parallel)
"""
import os
import sys

SHARDS = int(sys.argv[1])
BPS = int(sys.argv[2])
IMPL = sys.argv[3]

os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={SHARDS}"

import functools  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import distributed as dist  # noqa: E402
from repro.core import rng, simple  # noqa: E402

N_GLOBAL = 4 * SHARDS * BPS          # reservoir target (scaled w/ stream rate)
CAP_S = 8 * BPS
LAM = 0.07
D = 8                                 # item payload: D int32s ~ a record


def main():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((SHARDS,), (dist.AXIS,))
    step = functools.partial(dist.drtbs_shard_step, n=N_GLOBAL, lam=LAM)

    def shard_fn(key, items, nfull, partial, weight, tweight, oflow, bi, bc):
        st = dist.DRTBSShard(
            items=items, nfull=nfull[0], partial_item=partial,
            weight=weight, total_weight=tweight, overflow=oflow[0],
        )
        me = jax.lax.axis_index(dist.AXIS)
        if IMPL == "cp_cent":
            # centralized decisions: a master-style GLOBAL slot permutation is
            # computed (replicated) before the step
            gperm = jax.random.permutation(key, N_GLOBAL)
            bi = bi + (gperm[0] * 0)
        if IMPL in ("kv_cj", "kv_rj"):
            # key-value reservoir: inserted payloads must cross the network to
            # hash-owned slots; RJ crosses twice (repartition join)
            gathered = jax.lax.all_gather(bi, dist.AXIS)
            bi = bi + 0 * gathered.sum(axis=0)
            if IMPL == "kv_rj":
                gathered2 = jax.lax.all_gather(bi, dist.AXIS)
                bi = bi + 0 * gathered2.sum(axis=0)
        if IMPL == "dttbs":
            import math

            p = math.exp(-LAM)
            q = min(1.0, N_GLOBAL * (1 - p) / (SHARDS * BPS))
            bst = simple.BufferState(
                items=items, count=nfull[0],
                total_weight=weight, overflow=oflow[0],
            )
            bst = dist.dttbs_shard_step(
                key, bst, bi, bc[0], p=jnp.float32(p), q=jnp.float32(q)
            )
            return (bst.items, bst.count[None], partial, weight,
                    bst.total_weight, bst.overflow[None])
        st = step(key, st, bi, bc[0])
        return (st.items, st.nfull[None], st.partial_item, st.weight,
                st.total_weight, st.overflow[None])

    smapped = jax.jit(
        dist.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(P(), P(dist.AXIS), P(dist.AXIS), P(), P(), P(),
                      P(dist.AXIS), P(dist.AXIS), P(dist.AXIS)),
            out_specs=(P(dist.AXIS), P(dist.AXIS), P(), P(), P(),
                       P(dist.AXIS)),
        )
    )

    items = jnp.zeros((SHARDS * CAP_S, D), jnp.int32)
    nfull = jnp.zeros((SHARDS,), jnp.int32)
    partial = jnp.zeros((D,), jnp.int32)
    weight = jnp.float32(0.0)
    tweight = jnp.float32(0.0)
    oflow = jnp.zeros((SHARDS,), jnp.int32)
    bi = jnp.ones((SHARDS * BPS, D), jnp.int32)
    bc = jnp.full((SHARDS,), BPS, jnp.int32)

    state = (items, nfull, partial, weight, tweight, oflow)
    # warmup (fills the reservoir, compiles)
    for t in range(3):
        key = jax.random.fold_in(jax.random.key(0), t)
        state = smapped(key, *state, bi, bc)
    jax.block_until_ready(state)
    ts = []
    for t in range(10):
        key = jax.random.fold_in(jax.random.key(1), t)
        t0 = time.perf_counter()
        state = smapped(key, *state, bi, bc)
        jax.block_until_ready(state)
        ts.append(time.perf_counter() - t0)
    print(f"{IMPL},{np.median(ts)*1e6:.1f}")


if __name__ == "__main__":
    main()
