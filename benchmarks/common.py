"""Shared benchmark helpers."""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

#: every BENCH_*.json written by a suite follows this shape (validated by
#: benchmarks/check_bench.py and the CI bench-smoke job):
#:   {"benchmark": str, "generated_unix": float, "jax": str, "backend": str,
#:    "smoke": bool, "provenance": {...}, "rows": [{"name": str,
#:    "us_per_call": float, ...derived}]}
BENCH_SCHEMA_KEYS = ("benchmark", "generated_unix", "jax", "backend", "smoke",
                     "provenance", "rows")

#: the run-provenance block every BENCH_*.json must carry so a number can be
#: traced to the software + hardware + tree that produced it
PROVENANCE_KEYS = ("jax", "backend", "device_kind", "commit", "timestamp")


def run_provenance() -> dict:
    """Where/when/what produced this benchmark run: jax version, backend and
    device kind, the repo commit (None outside a git checkout), and a UTC
    timestamp. Embedded in every BENCH_*.json (and usable by any other
    artifact writer)."""
    import datetime
    import subprocess

    import jax

    try:
        proc = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=str(REPO_ROOT),
            capture_output=True, text=True, timeout=10,
        )
        commit = proc.stdout.strip() if proc.returncode == 0 else None
    except Exception:  # noqa: BLE001 -- no git binary / not a checkout
        commit = None
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "commit": commit or None,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc
        ).isoformat(timespec="seconds"),
    }


def smoke_mode() -> bool:
    """CI smoke sizing: tiny caps / dry-run-length streams. Enabled by the
    ``--smoke`` flag of the benchmark mains or ``BENCH_SMOKE=1`` (the env var
    reaches suites invoked through benchmarks.run)."""
    import os
    import sys

    return "--smoke" in sys.argv or os.environ.get("BENCH_SMOKE") == "1"


def write_bench_json(benchmark: str, rows, *, smoke: bool | None = None):
    """Write ``BENCH_<benchmark>.json`` at the repo root from ``emit``-style
    rows, so the perf trajectory is machine-readable PR-over-PR instead of
    living only in stdout. Returns the path."""
    import jax

    payload = {
        "benchmark": benchmark,
        "generated_unix": time.time(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "smoke": smoke_mode() if smoke is None else smoke,
        "provenance": run_provenance(),
        "rows": [
            {"name": name, "us_per_call": round(float(us), 2), **derived}
            for name, us, derived in rows
        ],
    }
    path = REPO_ROOT / f"BENCH_{benchmark}.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def time_fn(fn, *args, warmup=2, iters=10):
    """Median wall-time per call in microseconds (jit-compiled callables)."""
    import jax

    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV lines."""
    for name, us, derived in rows:
        dstr = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.1f},{dstr}", flush=True)
