"""Shared benchmark helpers."""
from __future__ import annotations

import time

import numpy as np


def time_fn(fn, *args, warmup=2, iters=10):
    """Median wall-time per call in microseconds (jit-compiled callables)."""
    import jax

    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV lines."""
    for name, us, derived in rows:
        dstr = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.1f},{dstr}", flush=True)
