"""Paper Table 1 / Figures 10, 14: kNN accuracy (miss%) and robustness
(10% expected shortfall) under single-event and periodic drift, for R-TBS vs
sliding window (SW) vs uniform reservoir (Unif).

Reduced scale vs the paper (runs/warmup trimmed for the 1-core CPU harness;
EXPERIMENTS.md records the reduction) -- the paper's qualitative ordering
(R-TBS best-or-tied accuracy, clearly best ES; SW spikes on re-drift; Unif
never adapts) is what the derived columns reproduce."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rtbs, simple
from repro.data.streams import GMMStream, mode_schedule
from repro.models.simple_ml import expected_shortfall, knn_predict

ITEM = {"x": jax.ShapeDtypeStruct((2,), jnp.float32),
        "y": jax.ShapeDtypeStruct((), jnp.int32)}
N = 400          # sample size (paper: 1000)
B = 100          # batch size
WARM = 25        # warm-up batches (paper: 100)
T = 40           # evaluated batches (paper: 30+)
K = 7


def _method_step(method, key, st, items, bcount, lam):
    if method == "rtbs":
        return rtbs.step(key, st, items, bcount, n=N, lam=lam)
    if method == "sw":
        return simple.sw_step(key, st, items, bcount, n=N)
    return simple.brs_step(key, st, items, bcount, n=N)


def _sample_xy(method, key, st):
    if method == "rtbs":
        mask, _ = rtbs.realize(key, st)
        return st.lat.items["x"], st.lat.items["y"], mask
    mask, _ = simple.realize_all(st)
    return st.items["x"], st.items["y"], mask


def run_pattern(method, pattern, lam, seed=0):
    g = GMMStream(seed=seed)
    st = rtbs.init(ITEM, N) if method == "rtbs" else simple.init(ITEM, N)
    miss = []
    for t in range(WARM + T):
        mode = 0 if t < WARM else mode_schedule(
            pattern, t - WARM, delta=10, eta=10, start=10, stop=20
        )
        x, y = g.batch(t, B, mode)
        items = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
        key = jax.random.fold_in(jax.random.key(seed + 17), t)
        if t >= WARM:
            sx, sy, mask = _sample_xy(method, jax.random.fold_in(key, 1), st)
            pred = knn_predict(sx, sy, mask, jnp.asarray(x), k=K, num_classes=100)
            miss.append(float((np.asarray(pred) != y).mean()) * 100)
        st = _method_step(method, key, st, items, jnp.int32(B), lam)
    # paper: ES measured from t=20 onward for periodic (skip the first change)
    tail = miss[20:] if len(miss) > 25 else miss
    return float(np.mean(miss)), expected_shortfall(tail, 0.10)


def run():
    rows = []
    for pattern in ("single", "periodic"):
        for lam in (0.07, 0.10):
            for method in ("rtbs", "sw", "unif"):
                if method != "rtbs" and lam != 0.07:
                    continue  # SW/Unif are lambda-independent
                t0 = time.perf_counter()
                accs, ess = zip(*[
                    run_pattern(method, pattern, lam, seed=s) for s in range(3)
                ])
                us = (time.perf_counter() - t0) / 3 * 1e6
                rows.append((
                    f"table1_knn_{pattern}_{method}_lam{lam}",
                    us,
                    {"miss_pct": round(float(np.mean(accs)), 2),
                     "es10_pct": round(float(np.mean(ess)), 2)},
                ))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
