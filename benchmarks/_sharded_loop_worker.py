"""Subprocess worker for the sharded manage-loop benchmark.

Usage: python -m benchmarks._sharded_loop_worker <shards> <mode>
Prints: ``<mode>,<us_per_tick>``.

Modes (same sampler/model/stream/keys, so the traces are identical -- the
bit-equality is unit-tested in tests/test_sharded_loop.py):
  fused    -- :func:`repro.manage.make_sharded_run_loop`: the whole stream as
              one jitted scan with shard-resident reservoir state.
  per_tick -- :func:`repro.manage.make_sharded_manage_step`: one shard_map
              dispatch per tick, state round-tripped through its replicated
              gather_tree snapshot (the pre-fusion idiom).
"""
import os
import sys

SHARDS = int(sys.argv[1])
MODE = sys.argv[2]

os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={SHARDS}"

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.api import make_sampler  # noqa: E402
from repro.data.streams import LinRegStream  # noqa: E402
from repro.launch.mesh import make_data_mesh  # noqa: E402
from repro.manage import (  # noqa: E402
    init_sharded_state,
    make_model,
    make_sharded_manage_step,
    make_sharded_run_loop,
    materialize_stream,
    shard_stream,
)

T = 64
B_PER_SHARD = 64           # global batch scales with the mesh
N = 256
LAM = 0.07
RETRAIN_EVERY = 4


def main():
    sampler = make_sampler("drtbs", n=N, lam=LAM,
                           cap_s=N + B_PER_SHARD)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(
        LinRegStream(seed=0), T, batch_size=B_PER_SHARD * SHARDS
    )
    batches, bcounts = shard_stream(batches, bcounts, SHARDS)
    mesh = make_data_mesh(SHARDS)
    key = jax.random.key(0)

    if MODE == "fused":
        run = make_sharded_run_loop(sampler, model, mesh,
                                    retrain_every=RETRAIN_EVERY)

        def once():
            return run(key, batches, bcounts)

    elif MODE == "per_tick":
        tick = make_sharded_manage_step(sampler, model, mesh,
                                        retrain_every=RETRAIN_EVERY)
        proto = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape[2:], a.dtype), batches
        )
        ticks = [
            (jnp.int32(t),
             jax.tree_util.tree_map(lambda a, t=t: a[t], batches),
             bcounts[t])
            for t in range(T)
        ]

        def once():
            state = init_sharded_state(sampler, SHARDS, proto)
            params = model.init()
            for t, bt, ct in ticks:
                state, params, m = tick(key, t, state, params, bt, ct)
            return state, params, m

    else:
        raise SystemExit(f"unknown mode {MODE!r}")

    out = once()  # compile + warm
    jax.block_until_ready(out)
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        out = once()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    print(f"{MODE},{np.median(ts) / T * 1e6:.1f}")


if __name__ == "__main__":
    main()
