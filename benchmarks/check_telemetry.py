"""Validate a telemetry JSONL stream against the repro.obs record schema.

Usage: ``python -m benchmarks.check_telemetry path/to/telemetry.jsonl [...]``
Exits non-zero on the first violation -- the CI telemetry job gates on this
after running a short instrumented loop, so the drained record format
(DESIGN.md Sec. 14) stays parseable for downstream dashboards.

Checks per file: every line is a JSON object with a known ``kind``; the
stream opens with a ``run`` header carrying the static run facts; ``tick``
records carry the required gauge columns with sane types, and their ``t``
values are non-decreasing (drains are ordered); ``warning`` records carry
``monitor`` + ``message``.
"""
from __future__ import annotations

import json
import pathlib
import sys

KINDS = ("run", "tick", "warning", "query")

RUN_KEYS = ("run", "ticks", "every", "backend", "jax")
TICK_KEYS = ("t", "metric", "size")
WARNING_KEYS = ("monitor", "message")
QUERY_KEYS = ("query", "tokens_served")


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    try:
        lines = path.read_text().splitlines()
    except OSError as e:
        return [f"{path.name}: unreadable ({e})"]
    if not lines:
        return [f"{path.name}: empty telemetry stream"]
    records = []
    for i, line in enumerate(lines):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"{path.name}:{i + 1}: not JSON ({e})")
            continue
        if not isinstance(rec, dict):
            errors.append(f"{path.name}:{i + 1}: record must be an object")
            continue
        if rec.get("kind") not in KINDS:
            errors.append(
                f"{path.name}:{i + 1}: unknown kind {rec.get('kind')!r}"
            )
            continue
        records.append((i + 1, rec))
    if not records:
        return errors or [f"{path.name}: no valid records"]

    serve = any(r.get("mode") == "serve" for _, r in records
                if r["kind"] == "run")
    first = records[0][1]
    if first["kind"] != "run":
        errors.append(f"{path.name}: stream must open with a run header; "
                      f"got kind={first['kind']!r}")
    elif not serve:
        for k in RUN_KEYS:
            if k not in first:
                errors.append(f"{path.name}: run header missing {k!r}")

    last_t = None
    for ln, rec in records:
        if rec["kind"] == "tick":
            for k in TICK_KEYS:
                if k not in rec:
                    errors.append(f"{path.name}:{ln}: tick missing {k!r}")
            t = rec.get("t")
            if not isinstance(t, int):
                errors.append(f"{path.name}:{ln}: tick t must be int")
            elif last_t is not None and t < last_t:
                errors.append(f"{path.name}:{ln}: tick t went backwards "
                              f"({last_t} -> {t}); drains must be ordered")
            else:
                last_t = t
        elif rec["kind"] == "warning":
            for k in WARNING_KEYS:
                if k not in rec:
                    errors.append(f"{path.name}:{ln}: warning missing {k!r}")
        elif rec["kind"] == "query":
            for k in QUERY_KEYS:
                if k not in rec:
                    errors.append(f"{path.name}:{ln}: query missing {k!r}")
        if rec["kind"] == "run":
            last_t = None  # a new run restarts the tick clock
    return errors


def main() -> None:
    paths = [pathlib.Path(a) for a in sys.argv[1:]]
    if not paths:
        raise SystemExit(
            "usage: python -m benchmarks.check_telemetry <telemetry.jsonl>..."
        )
    errors = []
    for p in paths:
        errors += check_file(p)
    for e in errors:
        print(f"TELEMETRY SCHEMA ERROR: {e}", file=sys.stderr)
    if errors:
        raise SystemExit(1)
    print(f"ok: {', '.join(p.name for p in paths)} valid")


if __name__ == "__main__":
    main()
