"""Paper Figures 7/8/9: distributed implementation comparison + scaling.

Fig. 7: per-batch time of the five implementation points on an 8-shard host
mesh. Expected ordering (as in the paper): D-T-TBS < CP+Dist < CP+Cent <
KV+CJ < KV+RJ. Host-mesh wall time measures total work + copies (all shards
share one CPU), so it reflects the paper's *work/traffic* ordering rather
than real network latency -- EXPERIMENTS.md notes the caveat.

Fig. 8 (scale-out): CP+Dist per-batch time vs shard count at fixed global
batch. Fig. 9 (scale-up): per-batch time vs per-shard batch size.

``fig8_fusedloop_*`` is the scale-out of the FULL fused sharded manage loop
(stream -> sample -> retrain -> eval via repro.manage.make_sharded_run_loop)
rather than the bare sampler step -- the Sec. 5 algorithms driving the
Sec. 6 experiment harness in one program (protocol in EXPERIMENTS.md)."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

HERE = pathlib.Path(__file__).parent
SRC = str(HERE.parent / "src")


def _worker(shards, bps, impl, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + str(HERE.parent) + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks._dist_worker",
         str(shards), str(bps), impl],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=str(HERE.parent),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = proc.stdout.strip().splitlines()[-1]
    return float(line.split(",")[1])


def run():
    rows = []
    # Fig. 7: implementation comparison at 8 shards
    for impl in ("dttbs", "cp_dist", "cp_cent", "kv_cj", "kv_rj"):
        us = _worker(8, 2048, impl)
        rows.append((f"fig7_impl_{impl}", us, {"shards": 8, "batch/shard": 2048}))
    # Fig. 8: scale-out (fixed global batch = 16384)
    for shards in (1, 2, 4, 8):
        us = _worker(shards, 16384 // shards, "cp_dist")
        rows.append((f"fig8_scaleout_{shards}w", us,
                     {"global_batch": 16384, "shards": shards}))
    # Fig. 9: scale-up (8 shards, growing batch)
    for bps in (512, 2048, 8192):
        us = _worker(8, bps, "cp_dist")
        rows.append((f"fig9_scaleup_b{bps}", us,
                     {"shards": 8, "batch/shard": bps}))
    # Fig. 8 companion: the whole fused manage loop scaling out
    from .manage_loop import _sharded_worker

    for shards in (1, 2, 4, 8):
        us = _sharded_worker(shards, "fused")
        rows.append((f"fig8_fusedloop_{shards}w", us,
                     {"shards": shards, "us_per_tick": round(us, 1)}))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
