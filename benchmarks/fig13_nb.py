"""Paper Figure 13: Naive Bayes on the Usenet2-like recurring-context stream
(the original dataset host is offline; the synthetic stand-in flips the
simulated user's interest profile every 300 messages -- EXPERIMENTS.md
documents the substitution). n=300, batch 50, lambda=0.3, 20% ES, all 30
batches scored (no warm-up), matching the paper's protocol.

Runs on the unified API: one fused :func:`repro.manage.make_run_loop` scan per
scheme, re-dispatched across stream seeds."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.api import make_sampler
from repro.data.streams import UsenetLikeStream
from repro.manage import make_model, make_run_loop, materialize_stream
from repro.models.simple_ml import expected_shortfall

B = 50
T = 30
N = 300
LAM = 0.3

SCHEMES = {
    "rtbs": lambda: make_sampler("rtbs", n=N, lam=LAM),
    "sw": lambda: make_sampler("sw", n=N),
    "unif": lambda: make_sampler("brs", n=N),
}


def run_one(run, seed=0):
    batches, bcounts = materialize_stream(
        UsenetLikeStream(seed=seed), T, batch_size=B
    )
    _, _, trace = run(jax.random.fold_in(jax.random.key(43), seed),
                      batches, bcounts)
    miss = np.asarray(trace["metric"])[1:] * 100  # t=0 scored an unfit model
    return float(np.mean(miss)), expected_shortfall(miss, 0.20)


def run():
    rows = []
    vocab = UsenetLikeStream().vocab
    model = make_model("naive_bayes", vocab=vocab)
    for method, build in SCHEMES.items():
        loop = make_run_loop(build(), model, retrain_every=1)
        run_one(loop, seed=0)  # compile outside the timed region
        t0 = time.perf_counter()
        out = [run_one(loop, seed=s) for s in range(3)]
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((
            f"fig13_nb_{method}",
            us,
            {"miss_pct": round(float(np.mean([o[0] for o in out])), 2),
             "es20_pct": round(float(np.mean([o[1] for o in out])), 2)},
        ))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
