"""Paper Figure 13: Naive Bayes on the Usenet2-like recurring-context stream
(the original dataset host is offline; the synthetic stand-in flips the
simulated user's interest profile every 300 messages -- EXPERIMENTS.md
documents the substitution). n=300, batch 50, lambda=0.3, 20% ES, all 30
batches scored (no warm-up), matching the paper's protocol."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rtbs, simple
from repro.data.streams import UsenetLikeStream
from repro.models.simple_ml import expected_shortfall, nb_fit, nb_predict

B = 50
T = 30
N = 300
LAM = 0.3


def run_one(method, seed=0):
    s = UsenetLikeStream(seed=seed)
    item = {"x": jax.ShapeDtypeStruct((s.vocab,), jnp.float32),
            "y": jax.ShapeDtypeStruct((), jnp.int32)}
    st = rtbs.init(item, N) if method == "rtbs" else simple.init(item, N)
    miss = []
    for t in range(T):
        x, y = s.batch(t, B)
        items = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
        key = jax.random.fold_in(jax.random.key(seed + 43), t)
        if t > 0:
            if method == "rtbs":
                mask, _ = rtbs.realize(jax.random.fold_in(key, 1), st)
                sx, sy = st.lat.items["x"], st.lat.items["y"]
            else:
                mask, _ = simple.realize_all(st)
                sx, sy = st.items["x"], st.items["y"]
            params = nb_fit(sx, sy, mask)
            pred = np.asarray(nb_predict(params, jnp.asarray(x)))
            miss.append(float((pred != y).mean()) * 100)
        if method == "rtbs":
            st = rtbs.step(key, st, items, jnp.int32(B), n=N, lam=LAM)
        elif method == "sw":
            st = simple.sw_step(key, st, items, jnp.int32(B), n=N)
        else:
            st = simple.brs_step(key, st, items, jnp.int32(B), n=N)
    return float(np.mean(miss)), expected_shortfall(miss, 0.20)


def run():
    rows = []
    for method in ("rtbs", "sw", "unif"):
        t0 = time.perf_counter()
        out = [run_one(method, seed=s) for s in range(3)]
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((
            f"fig13_nb_{method}",
            us,
            {"miss_pct": round(float(np.mean([o[0] for o in out])), 2),
             "es20_pct": round(float(np.mean([o[1] for o in out])), 2)},
        ))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
