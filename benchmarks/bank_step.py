"""Bank-step throughput: the fused keyed bank vs naive per-key dispatch.

Times ONE bank tick (route + vmapped tick maps + banked payload pass) on a
Zipf-keyed batch at growing key counts K with the TOTAL batch fixed -- the
multi-tenant serving shape (EXPERIMENTS.md §Bank-throughput):

  * ``bank_rtbs_fused_K*`` / ``bank_ttbs_fused_K*`` --
    :func:`repro.bank.make_bank`'s step: work proportional to the batch
    (<= b touched keys advance; the other K - b keys take the O(K)
    pure-decay pending multiply).
  * ``bank_rtbs_vmap_ref_K*`` -- the baseline a naive implementation pays:
    ``vmap`` of :func:`repro.core.rtbs.step_ref` advancing EVERY key every
    tick over dense per-key routed sub-batches (empty for most keys), i.e.
    O(K * cap) payload work + K argsorts per tick. The dense routing is
    precomputed OUTSIDE the timed region (flattering the baseline).

The acceptance criterion (ISSUE 5): the fused bank beats the vmap-of-ref
baseline by >= 2x at K >= 4096 on CPU; ``speedup_vs_ref`` is recorded on
the fused rtbs rows. Emits ``BENCH_bank_step.json`` at the repo root.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank import make_bank, route, subbatches
from repro.core import rtbs

from .common import smoke_mode, write_bench_json

LAM = 0.05
D = 8


def _zipf_keys(rs, K, b, alpha=1.1):
    w = (1.0 + np.arange(K)) ** -alpha
    return rs.choice(K, size=b, p=w / w.sum()).astype(np.int32)


def _time(fn, *args, iters=10):
    for _ in range(2):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts) * 1e6)


def _dense_routed(keys, payload, K, bcap):
    """The baseline's input: every key's sub-batch as dense [K, bcap, D]
    rows + [K] counts (zero for the untouched majority)."""
    b = keys.shape[0]
    r = route(jnp.asarray(keys), jnp.int32(b), num_keys=K, bcap=bcap)
    sub = subbatches(r, payload, bcap=bcap)
    nt = int(r.ntouched)
    touched = np.asarray(r.touched)[:nt]
    dense = np.zeros((K, bcap, payload.shape[-1]), np.float32)
    counts = np.zeros((K,), np.int32)
    dense[touched] = np.asarray(sub)[:nt]
    counts[touched] = np.asarray(r.counts)[:nt]
    return jnp.asarray(dense), jnp.asarray(counts), nt


def bank_rows(K: int, b: int, cap_n: int, bcap: int, *, iters: int,
              with_baseline: bool):
    rs = np.random.RandomState(K)
    keys = jnp.asarray(_zipf_keys(rs, K, b))
    payload = jnp.asarray(rs.randn(b, D), np.float32)
    proto = jax.ShapeDtypeStruct((D,), jnp.float32)
    key0 = jax.random.key(0)

    rows = []
    fused_us = {}
    for scheme, hyper in [
        ("rtbs", dict(n=cap_n)),
        ("ttbs", dict(n=cap_n, batch_size=max(1.0, b / K), cap=cap_n + 1)),
    ]:
        bank = make_bank(scheme, num_keys=K, lam=LAM, bcap=bcap, **hyper)
        step = jax.jit(bank.step)
        st = bank.init(proto)
        for t in range(4):  # warm into a populated steady state
            st = step(jax.random.fold_in(key0, t), st, keys, payload,
                      jnp.int32(b))
        nt = int(route(keys, jnp.int32(b), num_keys=K, bcap=bcap).ntouched)
        us = _time(lambda k: step(k, st, keys, payload, jnp.int32(b)),
                   jax.random.fold_in(key0, 99), iters=iters)
        fused_us[scheme] = us
        rows.append((
            f"bank_{scheme}_fused_K{K}", us,
            {"scheme": scheme, "impl": "fused", "K": K, "cap": cap_n,
             "bcap": bcap, "batch": b, "keys_touched": nt,
             "keys_per_s": round(nt * 1e6 / us, 1),
             "items_per_s": round(b * 1e6 / us, 1)},
        ))

        # the production shape: ticks scanned, so the [K, cap, D] stack
        # updates in place (the scan carry aliases) instead of paying a
        # defensive whole-bank copy per dispatch like the row above
        G = 8

        @jax.jit
        def scan_steps(key, st):
            def body(c, i):
                return bank.step(jax.random.fold_in(key, i), c, keys,
                                 payload, jnp.int32(b)), None

            out, _ = jax.lax.scan(body, st, jnp.arange(G))
            return out

        us_s = _time(lambda k: scan_steps(k, st),
                     jax.random.fold_in(key0, 98), iters=iters) / G
        rows.append((
            f"bank_{scheme}_fused_scan_K{K}", us_s,
            {"scheme": scheme, "impl": "fused_scan", "K": K, "cap": cap_n,
             "bcap": bcap, "batch": b, "keys_touched": nt,
             "keys_per_s": round(nt * 1e6 / us_s, 1),
             "items_per_s": round(b * 1e6 / us_s, 1)},
        ))

    if with_baseline:
        # naive per-key dispatch: vmap(step_ref) advances ALL K keys
        dense, counts, nt = _dense_routed(keys, payload, K, bcap)
        st0 = jax.vmap(lambda _: rtbs.init(proto, cap_n))(jnp.arange(K))
        kvec = jax.vmap(lambda i: jax.random.fold_in(key0, i))(
            jnp.arange(K)
        )

        @jax.jit
        def vmap_ref(key, st):
            del key  # per-key streams pre-folded (outside the timed region)
            return jax.vmap(
                lambda kk, s, bt, c: rtbs.step_ref(kk, s, bt, c, n=cap_n,
                                                   lam=LAM)
            )(kvec, st, dense, counts)

        st = st0
        for _ in range(3):
            st = vmap_ref(key0, st)
        us = _time(lambda k: vmap_ref(k, st), key0, iters=max(3, iters // 3))
        speed = round(us / fused_us["rtbs"], 2)
        rows.append((
            f"bank_rtbs_vmap_ref_K{K}", us,
            {"scheme": "rtbs", "impl": "vmap_ref", "K": K, "cap": cap_n,
             "bcap": bcap, "batch": b, "keys_touched": nt,
             "keys_per_s": round(nt * 1e6 / us, 1),
             "items_per_s": round(b * 1e6 / us, 1)},
        ))
        # attach the criterion to the fused rtbs row of this K
        for i, (name, u, derived) in enumerate(rows):
            if name == f"bank_rtbs_fused_K{K}":
                derived["speedup_vs_ref"] = speed
                rows[i] = (name, u, derived)
    return rows


def run():
    smoke = smoke_mode()
    if smoke:
        grid = [(256, 64, 16, 8, True)]
        iters = 3
    else:
        # fixed total batch, growing K: the bank's work must stay ~flat
        # while the naive baseline grows linearly in K (timed at the
        # acceptance point K=4096; beyond that it only gets worse)
        grid = [(4096, 256, 64, 32, True), (16384, 256, 64, 32, False),
                (65536, 256, 64, 32, False)]
        iters = 10
    rows = []
    for K, b, cap_n, bcap, base in grid:
        rows += bank_rows(K, b, cap_n, bcap, iters=iters,
                          with_baseline=base)
    write_bench_json("bank_step", rows)
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
