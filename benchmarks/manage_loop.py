"""Fused vs. unfused online model-management loop (DESIGN.md Secs. 8, 10, 11).

Measures ticks/sec of the paper's stream -> sample -> retrain -> eval loop:

  * ``unfused`` -- the pre-API idiom: one Python iteration per tick calling
    individually jitted step/extract/fit/evaluate (4 dispatches/tick, metrics
    pulled to host each tick).
  * ``fused``   -- :func:`repro.manage.make_run_loop`: the whole stream in a
    single jitted ``lax.scan``.
  * ``fused_sb8``-- the same loop superbatched (G=8 chunked scan body: the
    non-retrain fast path drops the per-tick retrain conditional and scan
    bookkeeping; results bit-identical, asserted before timing).
  * ``farm32``  -- the fused loop ``vmap``-ed over 32 Monte-Carlo trials
    (Fig. 12/13 robustness protocol); throughput counts trials x ticks.

plus the sampler-step hot path at cap 4096 (the headline perf criterion:
fused + argsort-free vs the pre-fused reference, measured in both Alg. 2
phases -- see benchmarks/sampler_step.py for the full sweep), and the
D-R-TBS sharded loop at 1/2/4/8 virtual host devices (subprocess per device
count, see benchmarks/_sharded_loop_worker.py):

  * ``sharded_fused_Sw``   -- :func:`repro.manage.make_sharded_run_loop`:
    the whole stream as one jitted scan under shard_map (shard-resident
    reservoir state).
  * ``sharded_pertick_Sw`` -- :func:`repro.manage.make_sharded_manage_step`:
    one shard_map dispatch per tick (state snapshot round-trips every tick).

Same keys, same trace -- the fused/unfused equivalences are asserted before
timing (and unit-tested in tests/test_api.py / tests/test_sharded_loop.py).
Emits ``BENCH_manage_loop.json`` at the repo root; ``--smoke`` (or
BENCH_SMOKE=1) shrinks everything to CI size and skips the subprocess
points. EXPERIMENTS.md (sharded-loop + sampler-throughput protocols)
documents the host-mesh caveat.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

import jax
import numpy as np

from repro.core.api import make_sampler
from repro.data.streams import LinRegStream, mode_schedule
from repro.manage import (
    make_manage_step,
    make_model,
    make_run_farm,
    make_run_loop,
    materialize_stream,
)
from repro.manage.loop import item_proto

from .common import smoke_mode, time_fn, write_bench_json

T = 200
B = 100
N = 400
LAM = 0.07
TRIALS = 32
SB = 8                      # superbatch chunk size for the fused_sb rows
STEP_CAP = 4096             # sampler-step criterion capacity
STEP_BCAP = 512

HERE = pathlib.Path(__file__).parent


def _sharded_worker(shards: int, mode: str, timeout=600) -> float:
    """us/tick of the sharded loop in a subprocess with ``shards`` forced
    host devices (the device count is locked at jax init, so each point
    needs its own process -- same pattern as fig789)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        str(HERE.parent / "src") + os.pathsep + str(HERE.parent)
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    env.pop("XLA_FLAGS", None)  # the worker sets its own device count
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks._sharded_loop_worker",
         str(shards), mode],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=str(HERE.parent),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return float(proc.stdout.strip().splitlines()[-1].split(",")[1])


def run():
    smoke = smoke_mode()
    T_, B_, N_ = (40, 16, 64) if smoke else (T, B, N)
    retrain_every = SB  # so the superbatched loop has a cond-free fast path

    sampler = make_sampler("rtbs", n=N_, lam=LAM)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(
        LinRegStream(seed=0), T_, batch_size=B_,
        mode=lambda t: mode_schedule("periodic", t),
    )
    key = jax.random.key(0)

    tick = make_manage_step(sampler, model,  # jitted, donates off-CPU
                            retrain_every=retrain_every)
    fused = make_run_loop(sampler, model, retrain_every=retrain_every,
                          superbatch=1)
    fused_sb = make_run_loop(sampler, model, retrain_every=retrain_every,
                             superbatch=SB)
    farm = make_run_farm(sampler, model, retrain_every=retrain_every)

    def unfused(key, batches, bcounts):
        state = sampler.init(item_proto(batches))
        params = model.init()
        metrics = []
        for t in range(T_):
            bt = jax.tree_util.tree_map(lambda a: a[t], batches)
            state, params, m = tick(key, t, state, params, bt, bcounts[t])
            metrics.append(float(m["metric"]))  # host pull, as the old drivers did
        return state, params, np.asarray(metrics)

    # equivalence before timing: same keys => identical metric traces, and
    # the superbatched loop is bit-identical to the per-tick scan
    _, _, trace = fused(key, batches, bcounts)
    _, _, trace_sb = fused_sb(key, batches, bcounts)
    np.testing.assert_array_equal(np.asarray(trace["metric"]),
                                  np.asarray(trace_sb["metric"]))
    _, _, m_unfused = unfused(key, batches, bcounts)
    np.testing.assert_allclose(
        np.asarray(trace["metric"]), m_unfused, rtol=1e-5
    )

    rows = []
    t_unf = time_fn(unfused, key, batches, bcounts, iters=5) / 1e6  # -> s
    rows.append(("manage_loop_unfused", t_unf / T_ * 1e6,
                 {"ticks_per_s": round(T_ / t_unf, 1)}))

    t_fus = time_fn(fused, key, batches, bcounts) / 1e6
    rows.append(("manage_loop_fused", t_fus / T_ * 1e6,
                 {"ticks_per_s": round(T_ / t_fus, 1),
                  "speedup_vs_unfused": round(t_unf / t_fus, 2)}))

    t_sb = time_fn(fused_sb, key, batches, bcounts) / 1e6
    rows.append((f"manage_loop_fused_sb{SB}", t_sb / T_ * 1e6,
                 {"ticks_per_s": round(T_ / t_sb, 1),
                  "superbatch": SB,
                  "speedup_vs_fused": round(t_fus / t_sb, 2)}))

    t_farm = time_fn(farm, key, TRIALS, batches, bcounts) / 1e6
    work = T_ * TRIALS
    rows.append(("manage_loop_farm32", t_farm / work * 1e6,
                 {"trial_ticks_per_s": round(work / t_farm, 1),
                  "trials": TRIALS}))

    # the sampler-step perf criterion: fused + argsort-free vs the pre-fused
    # reference at cap >= 4096 (both Alg. 2 phases; full sweep in
    # benchmarks/sampler_step.py -> BENCH_sampler_step.json)
    from .sampler_step import rtbs_rows

    cap, bcap = (64, 16) if smoke else (STEP_CAP, STEP_BCAP)
    rows += rtbs_rows(cap, bcap, iters=5 if smoke else 30)

    # D-R-TBS sharded loop: fused scan vs per-tick shard_map dispatch
    if not smoke:
        for shards in (1, 2, 4, 8):
            us_tick = _sharded_worker(shards, "per_tick")
            us_fused = _sharded_worker(shards, "fused")
            rows.append((f"sharded_pertick_{shards}w", us_tick,
                         {"shards": shards,
                          "ticks_per_s": round(1e6 / us_tick, 1)}))
            rows.append((f"sharded_fused_{shards}w", us_fused,
                         {"shards": shards,
                          "ticks_per_s": round(1e6 / us_fused, 1),
                          "speedup_vs_pertick": round(us_tick / us_fused, 2)}))
    write_bench_json("manage_loop", rows)
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
