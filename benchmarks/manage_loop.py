"""Fused vs. unfused online model-management loop (DESIGN.md Sec. 8).

Measures ticks/sec of the paper's stream -> sample -> retrain -> eval loop:

  * ``unfused`` -- the pre-API idiom: one Python iteration per tick calling
    individually jitted step/extract/fit/evaluate (4 dispatches/tick, metrics
    pulled to host each tick).
  * ``fused``   -- :func:`repro.manage.make_run_loop`: the whole stream in a
    single jitted ``lax.scan``.
  * ``farm32``  -- the fused loop ``vmap``-ed over 32 Monte-Carlo trials
    (Fig. 12/13 robustness protocol); throughput counts trials x ticks.

Same keys, same trace -- the fused/unfused equivalence is asserted before
timing (and unit-tested in tests/test_api.py).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.api import make_sampler
from repro.data.streams import LinRegStream, mode_schedule
from repro.manage import (
    make_manage_step,
    make_model,
    make_run_farm,
    make_run_loop,
    materialize_stream,
)
from repro.manage.loop import item_proto

from .common import time_fn

T = 200
B = 100
N = 400
LAM = 0.07
TRIALS = 32


def run():
    sampler = make_sampler("rtbs", n=N, lam=LAM)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(
        LinRegStream(seed=0), T, batch_size=B,
        mode=lambda t: mode_schedule("periodic", t),
    )
    key = jax.random.key(0)

    tick = jax.jit(make_manage_step(sampler, model), static_argnames=())
    fused = make_run_loop(sampler, model)
    farm = make_run_farm(sampler, model)

    def unfused(key, batches, bcounts):
        state = sampler.init(item_proto(batches))
        params = model.init()
        metrics = []
        for t in range(T):
            bt = jax.tree_util.tree_map(lambda a: a[t], batches)
            state, params, m = tick(key, t, state, params, bt, bcounts[t])
            metrics.append(float(m["metric"]))  # host pull, as the old drivers did
        return state, params, np.asarray(metrics)

    # equivalence before timing: same keys => identical metric traces
    _, _, trace = fused(key, batches, bcounts)
    _, _, m_unfused = unfused(key, batches, bcounts)
    np.testing.assert_allclose(
        np.asarray(trace["metric"]), m_unfused, rtol=1e-5
    )

    rows = []
    t_unf = time_fn(unfused, key, batches, bcounts, iters=5) / 1e6  # -> s
    rows.append(("manage_loop_unfused", t_unf / T * 1e6,
                 {"ticks_per_s": round(T / t_unf, 1)}))

    t_fus = time_fn(fused, key, batches, bcounts) / 1e6
    rows.append(("manage_loop_fused", t_fus / T * 1e6,
                 {"ticks_per_s": round(T / t_fus, 1),
                  "speedup_vs_unfused": round(t_unf / t_fus, 2)}))

    t_farm = time_fn(farm, key, TRIALS, batches, bcounts) / 1e6
    work = T * TRIALS
    rows.append(("manage_loop_farm32", t_farm / work * 1e6,
                 {"trial_ticks_per_s": round(work / t_farm, 1),
                  "trials": TRIALS}))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
