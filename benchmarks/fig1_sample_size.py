"""Paper Figure 1: sample-size behaviour of T-TBS vs R-TBS under four
batch-size regimes -- (a) growing (T-TBS overflows, R-TBS pinned at n),
(b) constant (T-TBS fluctuates, R-TBS constant), (c) uniform-random,
(d) decaying (both shrink -- a feature, Sec. 1)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rtbs, simple
from repro.data.streams import batch_size_schedule

from .common import time_fn

PROTO = jax.ShapeDtypeStruct((), jnp.int32)


def _run_regime(kind, lam, n, T, b=100, phi=None, cap=None):
    cap = cap or 16 * n
    phi_kw = {} if phi is None else {"phi": phi}
    sizes_t, sizes_r, overflowed = [], [], 0
    p = math.exp(-lam)
    q = min(1.0, n * (1 - p) / b)
    st_t = simple.init(PROTO, cap)
    st_r = rtbs.init(PROTO, n)
    bcap = max(batch_size_schedule(kind, t, b=b, **phi_kw) for t in range(T)) + 1
    bcap = min(bcap, 4 * cap)
    for t in range(T):
        bs = min(batch_size_schedule(kind, t, b=b, seed=t, **phi_kw), bcap)
        items = jnp.ones((bcap,), jnp.int32)
        key = jax.random.fold_in(jax.random.key(0), t)
        st_t = simple.ttbs_step(key, st_t, items, jnp.int32(bs),
                                p=jnp.float32(p), q=jnp.float32(q))
        st_r = rtbs.step(key, st_r, items, jnp.int32(bs), n=n, lam=lam)
        sizes_t.append(int(st_t.count))
        sizes_r.append(float(st_r.lat.weight))
    return np.asarray(sizes_t), np.asarray(sizes_r), int(st_t.overflow)


def run():
    rows = []
    n = 1000
    regimes = [
        ("fig1a_growing", "growing", 0.05, 1.002, 400),
        ("fig1b_constant", "constant", 0.1, None, 300),
        ("fig1c_uniform", "uniform", 0.1, None, 300),
        ("fig1d_decaying", "decaying", 0.01, 0.8, 300),
    ]
    for name, kind, lam, phi, T in regimes:
        st, sr, ovf = _run_regime(kind, lam, n, T, phi=phi)
        derived = {
            "ttbs_max": int(st.max()),
            "ttbs_final": int(st[-1]),
            "rtbs_max": round(float(sr.max()), 1),
            "rtbs_final": round(float(sr[-1]), 1),
            "ttbs_overflow_drops": ovf,
            "rtbs_bounded": bool(sr.max() <= n + 1e-3),
        }
        # one timed step for the us_per_call column
        st_r = rtbs.init(PROTO, n)
        items = jnp.ones((128,), jnp.int32)
        us = time_fn(
            lambda k: rtbs.step(k, st_r, items, jnp.int32(100), n=n, lam=lam),
            jax.random.key(1),
        )
        rows.append((name, us, derived))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
