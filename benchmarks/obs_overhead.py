"""Telemetry overhead: the fused loops with in-scan telemetry ON vs OFF.

The acceptance criterion for repro.obs (DESIGN.md Sec. 14) is that the
in-scan stats rows + boundary drains cost <= 5% on the hot paths:

  * ``obs_manage_cap4096``  -- :func:`repro.manage.make_run_loop` over the
    R-TBS fused sampler at the sampler-step criterion sizing (cap 4096,
    bcap 512, saturated steady state -- the ``rtbs_fused_sat_cap4096``
    configuration of benchmarks/sampler_step.py, run as a loop);
  * ``obs_bank_K4096``      -- the K=4096 bank step: ``step_stats`` (the
    stats-returning closure every instrumented loop drives) vs ``step``.

Both points time telemetry-off and telemetry-on over the same inputs and
record ``overhead_pct``; the telemetry handle drains into an in-memory sink
at the default 64-tick period, so the measured cost includes row stacking,
the drain callback, and host fan-out -- the full instrumented path. Equality
of the on/off traces is asserted before timing (the bit-identity contract,
unit-tested in tests/test_obs.py). Emits ``BENCH_obs_overhead.json``
(EXPERIMENTS.md §Telemetry-overhead).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank import make_bank
from repro.core.api import make_sampler
from repro.data.streams import LinRegStream, mode_schedule
from repro.manage import make_model, make_run_loop, materialize_stream
from repro.obs import MemorySink, Telemetry

from .common import smoke_mode, write_bench_json

LAM = 0.05
D = 8
CAP = 4096
BCAP = 512
K = 4096
EVERY = 64


def _best_of_pair(fa, fb, iters, *args):
    """Best-of-N wall seconds for two functions over the same inputs,
    measured INTERLEAVED (a, b, a, b, ...) so CPU frequency / load drift
    hits both sides equally -- an on/off overhead ratio from sequential
    blocks can swing several percent on a busy host. Min per side: noise
    only adds time."""
    jax.block_until_ready(fa(*args))
    jax.block_until_ready(fb(*args))
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fa(*args))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb(*args))
        tb.append(time.perf_counter() - t0)
    return float(np.min(ta)), float(np.min(tb))


def _tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def manage_rows(cap: int, bcap: int, T: int, iters: int):
    """The fused manage loop at the rtbs_fused_sat_cap4096 sizing, telemetry
    on vs off over an identical stream."""
    sampler = make_sampler("rtbs", n=cap, lam=LAM)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(
        LinRegStream(seed=0), T, batch_size=bcap,
        mode=lambda t: mode_schedule("periodic", t),
    )
    key = jax.random.key(0)
    retrain_every = 8

    off = make_run_loop(sampler, model, retrain_every=retrain_every,
                        superbatch=8)
    tel = Telemetry([MemorySink(capacity=4 * T)], every=EVERY, monitors=())
    on = make_run_loop(sampler, model, retrain_every=retrain_every,
                       superbatch=8, telemetry=tel)

    out_off = off(key, batches, bcounts)
    out_on = on(key, batches, bcounts)
    _tree_equal(out_off, out_on)  # the bit-identity contract

    t_off, t_on = _best_of_pair(off, on, iters, key, batches, bcounts)
    pct = (t_on - t_off) / t_off * 100
    us_off, us_on = t_off / T * 1e6, t_on / T * 1e6
    return [
        (f"obs_manage_cap{cap}_off", us_off,
         {"telemetry": "off", "cap": cap, "bcap": bcap, "ticks": T,
          "ticks_per_s": round(T / t_off, 1)}),
        (f"obs_manage_cap{cap}_on", us_on,
         {"telemetry": "on", "cap": cap, "bcap": bcap, "ticks": T,
          "every": EVERY, "ticks_per_s": round(T / t_on, 1),
          "overhead_pct": round(pct, 2)}),
    ]


def bank_rows(K: int, T: int, iters: int):
    """The K-key bank step: the stats-returning closure vs the plain step."""
    n, bcap, b = 64, 32, 256
    bank = make_bank("rtbs", num_keys=K, n=n, lam=LAM, bcap=bcap)
    rng = np.random.default_rng(0)
    keys_np = rng.integers(0, K, (T, b)).astype(np.int32)
    payload = jnp.asarray(rng.normal(size=(b, D)).astype(np.float32))
    proto = jax.ShapeDtypeStruct((D,), jnp.float32)
    state = bank.init(proto)
    key = jax.random.key(0)
    step = jax.jit(bank.step)
    step_stats = jax.jit(bank.step_stats)
    for t in range(4):  # warm to steady state + compile both
        kt = jax.random.fold_in(key, t)
        kj = jnp.asarray(keys_np[t])
        state = step(kt, state, kj, payload, jnp.int32(b))
        st2, _ = step_stats(kt, state, kj, payload, jnp.int32(b))
    _tree_equal(step(key, state, jnp.asarray(keys_np[0]), payload,
                     jnp.int32(b)),
                step_stats(key, state, jnp.asarray(keys_np[0]), payload,
                           jnp.int32(b))[0])
    kj = jnp.asarray(keys_np[0])

    t_off, t_on = _best_of_pair(step, step_stats, iters, key, state, kj,
                                payload, jnp.int32(b))
    pct = (t_on - t_off) / t_off * 100
    return [
        (f"obs_bank_K{K}_off", t_off * 1e6,
         {"telemetry": "off", "K": K, "bcap": bcap, "b": b,
          "steps_per_s": round(1 / t_off, 1)}),
        (f"obs_bank_K{K}_on", t_on * 1e6,
         {"telemetry": "on", "K": K, "bcap": bcap, "b": b,
          "steps_per_s": round(1 / t_on, 1),
          "overhead_pct": round(pct, 2)}),
    ]


def run():
    smoke = smoke_mode()
    cap, bcap, T, iters = (64, 16, 32, 3) if smoke else (CAP, BCAP, 128, 9)
    kk, tk = (64, 8) if smoke else (K, 8)
    rows = manage_rows(cap, bcap, T, iters)
    rows += bank_rows(kk, tk, iters=3 if smoke else 30)
    write_bench_json("obs_overhead", rows)
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
