"""Paper Figure 12: linear-regression MSE under Periodic(10,10) drift --
(a) saturated samples (n=400 here), (b) unsaturated R-TBS (n larger than the
equilibrium weight), plus 10% ES robustness. Reduced scale; qualitative
claims reproduced: R-TBS best MSE and best-or-near-best ES; in the
unsaturated regime R-TBS beats SW/Unif DESPITE a smaller realized sample
("more data is not always better", Sec. 6.3)."""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rtbs, simple
from repro.data.streams import LinRegStream, mode_schedule
from repro.models.simple_ml import expected_shortfall, linreg_fit, linreg_predict

ITEM = {"x": jax.ShapeDtypeStruct((2,), jnp.float32),
        "y": jax.ShapeDtypeStruct((), jnp.float32)}
B = 100
WARM = 25
T = 40
LAM = 0.07


def run_one(method, n, seed=0):
    s = LinRegStream(seed=seed)
    st = rtbs.init(ITEM, n) if method == "rtbs" else simple.init(ITEM, n)
    mses = []
    sample_sizes = []
    for t in range(WARM + T):
        mode = 0 if t < WARM else mode_schedule("periodic", t - WARM)
        x, y = s.batch(t, B, mode)
        items = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
        key = jax.random.fold_in(jax.random.key(seed + 31), t)
        if t >= WARM:
            if method == "rtbs":
                mask, size = rtbs.realize(jax.random.fold_in(key, 1), st)
                sx, sy = st.lat.items["x"], st.lat.items["y"]
            else:
                mask, size = simple.realize_all(st)
                sx, sy = st.items["x"], st.items["y"]
            coef = linreg_fit(sx, sy, mask)
            pred = np.asarray(linreg_predict(coef, jnp.asarray(x)))
            mses.append(float(np.mean((pred - y) ** 2)))
            sample_sizes.append(int(size))
        if method == "rtbs":
            st = rtbs.step(key, st, items, jnp.int32(B), n=n, lam=LAM)
        elif method == "sw":
            st = simple.sw_step(key, st, items, jnp.int32(B), n=n)
        else:
            st = simple.brs_step(key, st, items, jnp.int32(B), n=n)
    return (float(np.mean(mses)), expected_shortfall(mses[20:], 0.10),
            float(np.mean(sample_sizes)))


def run():
    rows = []
    eq_weight = B / (1 - math.exp(-LAM))  # R-TBS equilibrium ~ 1479 @ B=100
    for regime, n in (("saturated", 400), ("unsaturated", 1600)):
        for method in ("rtbs", "sw", "unif"):
            t0 = time.perf_counter()
            out = [run_one(method, n, seed=s) for s in range(3)]
            us = (time.perf_counter() - t0) / 3 * 1e6
            mse = float(np.mean([o[0] for o in out]))
            es = float(np.mean([o[1] for o in out]))
            sz = float(np.mean([o[2] for o in out]))
            rows.append((
                f"fig12_linreg_{regime}_{method}",
                us,
                {"mse": round(mse, 3), "es10": round(es, 3),
                 "avg_sample": round(sz, 1),
                 "equilibrium_weight": round(eq_weight, 1)},
            ))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
