"""Paper Figure 12: linear-regression MSE under Periodic(10,10) drift --
(a) saturated samples (n=400 here), (b) unsaturated R-TBS (n larger than the
equilibrium weight), plus 10% ES robustness. Reduced scale; qualitative
claims reproduced: R-TBS best MSE and best-or-near-best ES; in the
unsaturated regime R-TBS beats SW/Unif DESPITE a smaller realized sample
("more data is not always better", Sec. 6.3).

Runs on the unified API: one fused :func:`repro.manage.make_run_loop` scan per
(scheme, regime), re-dispatched across stream seeds."""
from __future__ import annotations

import math
import time

import jax
import numpy as np

from repro.core.api import make_sampler
from repro.data.streams import LinRegStream, mode_schedule
from repro.manage import make_model, make_run_loop, materialize_stream
from repro.models.simple_ml import expected_shortfall

B = 100
WARM = 25
T = 40
LAM = 0.07

SCHEMES = {
    "rtbs": lambda n: make_sampler("rtbs", n=n, lam=LAM),
    "sw": lambda n: make_sampler("sw", n=n),
    "unif": lambda n: make_sampler("brs", n=n),
}


def run_one(run, seed=0):
    batches, bcounts = materialize_stream(
        LinRegStream(seed=seed), WARM + T, batch_size=B,
        mode=lambda t: 0 if t < WARM else mode_schedule("periodic", t - WARM),
    )
    _, _, trace = run(jax.random.fold_in(jax.random.key(31), seed),
                      batches, bcounts)
    mses = np.asarray(trace["metric"])[WARM:]
    sizes = np.asarray(trace["size"])[WARM:]
    return (float(np.mean(mses)), expected_shortfall(mses[20:], 0.10),
            float(np.mean(sizes)))


def run():
    rows = []
    model = make_model("linreg", dim=2)
    eq_weight = B / (1 - math.exp(-LAM))  # R-TBS equilibrium ~ 1479 @ B=100
    for regime, n in (("saturated", 400), ("unsaturated", 1600)):
        for method, build in SCHEMES.items():
            loop = make_run_loop(build(n), model, retrain_every=1)
            run_one(loop, seed=0)  # compile outside the timed region
            t0 = time.perf_counter()
            out = [run_one(loop, seed=s) for s in range(3)]
            us = (time.perf_counter() - t0) / 3 * 1e6
            mse = float(np.mean([o[0] for o in out]))
            es = float(np.mean([o[1] for o in out]))
            sz = float(np.mean([o[2] for o in out]))
            rows.append((
                f"fig12_linreg_{regime}_{method}",
                us,
                {"mse": round(mse, 3), "es10": round(es, 3),
                 "avg_sample": round(sz, 1),
                 "equilibrium_weight": round(eq_weight, 1)},
            ))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
