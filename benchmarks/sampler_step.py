"""Sampler-step throughput: fused hot path vs the pre-fused reference.

Times ONE sampler step (the inner operation of the paper's Sec. 5 hot loop)
at reservoir capacities up to 4096+ on the default backend:

  * ``rtbs_fused_*``  -- :func:`repro.core.rtbs.step`: composed slot map +
    single two-source payload pass, argsort-free swap-or-not RNG
    (DESIGN.md Sec. 11).
  * ``rtbs_ref_*``    -- :func:`repro.core.rtbs.step_ref`: the pre-fused
    implementation (per-stage gathers, widened-buffer insert, exact argsort
    permutations) -- i.e. "current main" before this optimization.
  * ``ttbs/brs``      -- the simpler schemes' steps (now argsort-free; no
    pre-fused twin kept, so throughput only).

Both phases of Alg. 2 are measured: ``sat`` (steady state: W >= n, victim
replacement) and ``unsat`` (fill-up / decay downsampling). Scalar
trajectories of fused and ref are asserted equal before timing. Emits
``BENCH_sampler_step.json`` at the repo root (EXPERIMENTS.md
§Sampler-throughput).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rtbs
from repro.core.api import make_sampler

from .common import smoke_mode, write_bench_json

LAM = 0.05
D = 8


def _warm(step, key, st, batch, bcount, ticks):
    for t in range(ticks):
        st = step(jax.random.fold_in(key, t), st, batch, jnp.int32(bcount))
    jax.block_until_ready(st)
    return st


def _time_step(step, key, st, batch, bcount, iters):
    """Best-of-N wall time (timeit's convention, applied to both impls
    alike): single-step latencies are ~1ms, where scheduler/allocator noise
    only ever ADDS time, so min is the contention-robust estimator."""
    for i in range(2):  # warm (jit cache + allocator)
        jax.block_until_ready(step(jax.random.fold_in(key, 1000 + i), st,
                                   batch, jnp.int32(bcount)))
    ts = []
    for i in range(iters):
        t0 = time.perf_counter()
        out = step(jax.random.fold_in(key, 2000 + i), st, batch,
                   jnp.int32(bcount))
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts) * 1e6)


def rtbs_rows(cap: int, bcap: int, iters: int = 30):
    """fused-vs-ref rows for R-TBS at reservoir capacity ``cap`` (= n)."""
    proto = jax.ShapeDtypeStruct((D,), jnp.float32)
    batch = jnp.ones((bcap, D), jnp.float32)
    key = jax.random.key(0)

    fused = jax.jit(functools.partial(rtbs.step, n=cap, lam=LAM))
    ref = jax.jit(functools.partial(rtbs.step_ref, n=cap, lam=LAM))

    # equivalence before timing: identical C/W scalar trajectories
    st_f = st_r = rtbs.init(proto, cap)
    for t in range(6):
        kt = jax.random.fold_in(key, t)
        st_f = fused(kt, st_f, batch, jnp.int32(bcap))
        st_r = ref(kt, st_r, batch, jnp.int32(bcap))
    np.testing.assert_allclose(float(st_f.lat.weight), float(st_r.lat.weight),
                               rtol=1e-5)
    np.testing.assert_allclose(float(st_f.total_weight),
                               float(st_r.total_weight), rtol=1e-5)

    # saturated steady state: warm until W >= n
    warm_ticks = max(8, 2 * cap // bcap)
    st_sat = _warm(fused, key, rtbs.init(proto, cap), batch, bcap, warm_ticks)
    assert float(st_sat.total_weight) >= cap, "stream too short to saturate"
    # unsaturated: a few fill-up ticks
    st_un = _warm(fused, key, rtbs.init(proto, cap), batch, bcap, 2)

    rows = []
    for phase, st in [("sat", st_sat), ("unsat", st_un)]:
        derived = {}
        for name, step in [("fused", fused), ("ref", ref)]:
            us = _time_step(step, key, st, batch, bcap, iters)
            derived[name] = {
                "scheme": "rtbs", "impl": name, "phase": phase, "cap": cap,
                "bcap": bcap, "steps_per_s": round(1e6 / us, 1),
                "items_per_s": round(bcap * 1e6 / us, 1), "us": us,
            }
        derived["fused"]["speedup_vs_ref"] = round(
            derived["ref"]["us"] / derived["fused"]["us"], 2
        )
        for name in ("fused", "ref"):
            d = derived[name]
            us = d.pop("us")
            rows.append((f"rtbs_{name}_{phase}_cap{cap}", us, d))
    return rows


def simple_rows(cap: int, bcap: int, iters: int = 30):
    """Throughput rows for the buffer schemes (argsort-free picks/keeps)."""
    proto = jax.ShapeDtypeStruct((D,), jnp.float32)
    batch = jnp.ones((bcap, D), jnp.float32)
    key = jax.random.key(1)
    rows = []
    for scheme, hyper in [
        ("ttbs", dict(n=cap, lam=LAM, batch_size=float(bcap), cap=2 * cap)),
        ("brs", dict(n=cap)),
    ]:
        s = make_sampler(scheme, **hyper)
        step = jax.jit(s.step)
        st = s.init(proto)
        st = _warm(step, key, st, batch, bcap, 6)
        us = _time_step(step, key, st, batch, bcap, iters)
        rows.append((
            f"{scheme}_step_cap{cap}", us,
            {"scheme": scheme, "impl": "fast", "phase": "steady", "cap": cap,
             "bcap": bcap, "steps_per_s": round(1e6 / us, 1),
             "items_per_s": round(bcap * 1e6 / us, 1)},
        ))
    return rows


def run():
    smoke = smoke_mode()
    caps = [(64, 16)] if smoke else [(1024, 256), (4096, 512)]
    iters = 5 if smoke else 30
    rows = []
    for cap, bcap in caps:
        rows += rtbs_rows(cap, bcap, iters=iters)
        rows += simple_rows(cap, bcap, iters=iters)
    write_bench_json("sampler_step", rows)
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
