"""One module per assigned architecture; each exports CONFIG (exact assignment
numbers) and SMOKE (reduced same-family config for CPU smoke tests)."""
