"""granite-20b [dense]: 52L d_model=6144 48H (GQA kv=1 == MQA) d_ff=24576
vocab=49152 -- llama-arch code model. [arXiv:2405.04324]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="granite_20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    act="swiglu",
)

SMOKE = ModelConfig(
    name="granite_20b_smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    act="swiglu",
)
