"""whisper-large-v3 [audio]: enc-dec, 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866, conv frontend STUB. [arXiv:2212.04356]

Per the assignment, the modality frontend is a stub: ``input_specs()`` provides
precomputed log-mel frame embeddings (1500 frames after the conv downsampler).
32 encoder layers (bidirectional) + 32 decoder layers (causal self-attn +
cross-attn). Sinusoidal positions (deviation from learned decoder positions is
noted in DESIGN.md -- keeps position tables O(1) for the mechanical 32k-decode
shape). Decode shapes lower the DECODER with a self-attn KV cache of the given
length + precomputed cross-attention K/V over the 1500 encoder frames.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper_large_v3",
    family="audio",
    num_layers=32,            # decoder layers
    encoder_layers=32,
    is_encoder_decoder=True,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    encoder_seq=1500,
    act="gelu",
    use_bias=True,
    rope_theta=0.0,           # 0 -> sinusoidal absolute positions, no RoPE
    embed_stub=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper_smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    is_encoder_decoder=True,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    encoder_seq=12,
    act="gelu",
    use_bias=True,
    rope_theta=0.0,
    embed_stub=True,
    tie_embeddings=True,
)
