"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.

Note: the assignment's config field says 40e top-8 (the inline hf pointer is
the smaller granite-3.0-1b-a400m sibling); we implement the stated 40e/top-8.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="granite_moe_3b",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    num_experts_per_tok=8,
    act="swiglu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite_moe_smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=512,
    num_experts=8,
    num_experts_per_tok=2,
    act="swiglu",
    tie_embeddings=True,
)
