"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

M-RoPE (temporal/height/width rotary sections) + dynamic-resolution vision
frontend; per the assignment the frontend is a STUB -- ``input_specs()`` feeds
precomputed patch embeddings alongside text tokens, and the backbone here is
the full transformer. [arXiv:2409.12191]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),   # half-dim rotary sections (t, h, w)
    act="swiglu",
    use_bias=True,                 # qwen2 uses qkv bias
    tie_embeddings=True,
    embed_stub=True,
)

SMOKE = ModelConfig(
    name="qwen2_vl_2b_smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mrope_sections=(2, 3, 3),
    act="swiglu",
    use_bias=True,
    tie_embeddings=True,
    embed_stub=True,
)
