"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768. The memory-pressure stressor of the assigned pool.
[hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral_large_123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    act="swiglu",
)

SMOKE = ModelConfig(
    name="mistral_large_smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=192,
    vocab_size=512,
    act="swiglu",
)
