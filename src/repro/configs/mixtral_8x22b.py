"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention. [arXiv:2401.04088]

SWA makes the decode KV cache bounded by the window, so this arch RUNS the
long_500k cell (sub-quadratic serving; DESIGN.md §5).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral_8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    num_experts_per_tok=2,
    sliding_window=4096,
    act="swiglu",
)

SMOKE = ModelConfig(
    name="mixtral_smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    num_experts=4,
    num_experts_per_tok=2,
    sliding_window=16,
    act="swiglu",
)
