"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 -- SSD (state-space duality). [arXiv:2405.21060]

Attention-free: decode state is O(1) in context length, so long_500k runs.
d_inner = 2*d_model = 2048, head_dim 64 -> 32 SSD heads.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2_370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2_smoke",
    family="ssm",
    num_layers=3,
    d_model=64,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_groups=1,
    ssm_chunk=8,
    tie_embeddings=True,
)
