"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 -- Mamba2 backbone + shared attention block.

The Zamba2 pattern: every ``attn_every`` Mamba2 layers, one attention+MLP block
whose WEIGHTS ARE SHARED across all invocations (each invocation has its own KV
cache). [arXiv:2411.15242]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2_2p7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_groups=1,
    attn_every=6,                  # 9 shared-attention invocations over 54 layers
    act="swiglu",
)

SMOKE = ModelConfig(
    name="zamba2_smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_groups=1,
    ssm_chunk=8,
    attn_every=2,
)
