"""Key-routing layer of the sampler bank (DESIGN.md Sec. 13).

A tick's arrivals come as ``(keys[b], payload)`` -- one key id per item, in
arrival order. The bank's batched step needs them as per-key sub-batches:
which keys arrived this tick, and where each key's items sit. :func:`route`
computes exactly that with ONE stable argsort over the batch -- O(b log b),
independent of the number of keys K -- plus O(b) segment bookkeeping and two
O(K)-free scatters (everything is sized by the batch, never by K):

  * sort items by key (invalid rows past ``bcount`` sort to a ``num_keys``
    sentinel at the end), so each key's items form a contiguous segment;
  * segment boundaries give the ``<= b`` distinct touched keys, each with its
    segment start and length.

Fixed shapes throughout (jit/scan/vmap-safe): the touched-key list is padded
to length ``b`` with the ``num_keys`` sentinel -- consumers scatter through it
with ``mode="drop"``. Per-key sub-batches have a STATIC capacity ``bcap``:
a key receiving more than ``bcap`` items in one tick keeps its FIRST ``bcap``
(arrival order -- the sort is stable) and the rest are dropped and counted in
``Routing.dropped``, the bank's visible overflow accounting (the same
engineering-bound discipline as :class:`repro.core.simple.BufferState`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Routing:
    """One tick's key-bucketing. All arrays are sized by the batch ``b``.

    ``order``: the stable key-sort permutation (gather the payload through it
    for key-contiguous rows); ``touched``: the distinct arriving keys in
    ascending order, padded with ``num_keys``; ``ntouched``: how many are
    real; ``starts``/``counts``: each touched key's segment start in the
    sorted order and its ACCEPTED length (clipped to the static per-key
    ``bcap``); ``dropped``: per-touched-key overflow beyond ``bcap``.
    Rows at or past ``ntouched`` carry the sentinel key and zero counts.
    """

    order: jax.Array    # [b] int32
    touched: jax.Array  # [b] int32, ascending distinct keys, num_keys-padded
    ntouched: jax.Array  # int32 scalar
    starts: jax.Array   # [b] int32
    counts: jax.Array   # [b] int32, <= bcap
    dropped: jax.Array  # [b] int32
    invalid: jax.Array  # int32 scalar: valid rows with out-of-range key ids

    @property
    def overflow(self) -> jax.Array:
        """Total items dropped by the per-key ``bcap`` bound this tick."""
        return self.dropped.sum()


def route(keys: jax.Array, bcount, *, num_keys: int, bcap: int) -> Routing:
    """Bucket one tick's ``(keys, payload)`` batch into per-key segments.

    ``keys`` is [b] int32; rows at or past ``bcount`` are ignored, and rows
    whose key id falls outside [0, num_keys) are DISCARDED and counted in
    ``Routing.invalid`` -- never clipped onto a real tenant's reservoir
    (the cross-tenant aliasing a traced clip would silently cause; sharded
    banks take LOCAL ids, see manage.shard_keyed_stream).
    ``num_keys``/``bcap`` are static. See the module docstring for the
    contract and cost model.
    """
    b = keys.shape[0]
    bcount = jnp.asarray(bcount, jnp.int32)
    keys = keys.astype(jnp.int32)
    in_range = (keys >= 0) & (keys < num_keys)
    valid = (jnp.arange(b, dtype=jnp.int32) < bcount)
    invalid = (valid & ~in_range).sum().astype(jnp.int32)
    valid = valid & in_range
    mk = jnp.where(valid, keys, jnp.int32(num_keys))
    order = jnp.argsort(mk).astype(jnp.int32)        # stable: arrival order
    sk = mk[order]                                   # key-contiguous
    prev = jnp.concatenate([jnp.full((1,), -1, sk.dtype), sk[:-1]])
    is_start = (sk != prev) & (sk < num_keys)
    seg = jnp.cumsum(is_start.astype(jnp.int32)) - 1  # segment id per row
    nt = is_start.sum().astype(jnp.int32)

    pos = jnp.arange(b, dtype=jnp.int32)
    # scatter per-segment facts through the segment id; rows of invalid items
    # (sk == num_keys) route to index b and drop
    live = sk < num_keys
    at = jnp.where(is_start, seg, b)
    touched = jnp.full((b,), num_keys, jnp.int32).at[at].set(sk, mode="drop")
    starts = jnp.zeros((b,), jnp.int32).at[at].set(pos, mode="drop")
    raw = jnp.zeros((b,), jnp.int32).at[jnp.where(live, seg, b)].add(
        1, mode="drop"
    )
    counts = jnp.minimum(raw, bcap)
    return Routing(order=order, touched=touched, ntouched=nt, starts=starts,
                   counts=counts, dropped=raw - counts, invalid=invalid)


def subbatches(r: Routing, payload, *, bcap: int):
    """Gather each touched key's sub-batch from the tick's payload: leaves
    [b, ...] -> [b(touched rows), bcap, ...].

    Row t holds touched key t's items in its first ``r.counts[t]`` slots
    (in arrival order); slots beyond the count are neighbouring keys'
    payload -- garbage the step masks via its ``bcount`` operand, exactly
    like the zero padding of a materialized stream. Rows past ``ntouched``
    are entirely garbage (their writes are dropped downstream)."""
    b = r.order.shape[0]
    idx = jnp.clip(
        r.starts[:, None] + jnp.arange(bcap, dtype=jnp.int32)[None, :],
        0, b - 1,
    )

    def one(leaf):
        return jnp.take(jnp.take(leaf, r.order, axis=0), idx, axis=0)

    return jax.tree_util.tree_map(one, payload)
