"""repro.bank -- keyed multi-tenant sampler banks (DESIGN.md Sec. 13).

Millions of per-entity time-biased samples advanced in one fused step:
K stacked reservoirs behind the ``init / step / extract`` protocol
(:class:`SamplerBank`, built by :func:`make_bank` -- the bank-level twin of
:func:`repro.core.api.make_sampler`), with key-routed ingestion
(:mod:`repro.bank.routing`), a banked payload kernel, and a lazy per-key
pending-decay fast path for the untouched keys. The bank-level
model-management loops live in :mod:`repro.manage.bank_loop`.
"""
from .bank import (  # noqa: F401
    BankState,
    SamplerBank,
    available_bank_schemes,
    make_bank,
    register_bank,
)
from .routing import Routing, route, subbatches  # noqa: F401
