"""Keyed multi-tenant sampler banks (DESIGN.md Sec. 13).

The paper maintains ONE temporally-biased sample per stream; per-user model
management needs K independent reservoirs decaying concurrently, with K in
the 10^5..10^6 range -- far past what per-key Python dispatch (or even a
``vmap`` that advances every key every tick) can serve. A
:class:`SamplerBank` stores all K reservoirs as one stacked
structure-of-arrays pytree -- payload leaves [K, cap, ...] plus per-key
scalar state -- behind the same ``init / step / extract`` closure protocol
as :class:`repro.core.api.Sampler`, and advances a tick in work proportional
to the BATCH, not to K:

  * **routing** (:mod:`repro.bank.routing`): one stable O(b log b) argsort
    buckets the tick's ``(keys, payload)`` arrivals into <= b per-key
    segments with a static per-key sub-batch capacity ``bcap`` (+ overflow
    accounting);
  * **touched keys** are advanced by the scheme's own fused tick composed
    per key (``vmap`` of :func:`repro.core.rtbs.tick_map` / the T-TBS slot
    map) and ONE banked payload pass
    (:func:`repro.kernels.tbs_step.ops.tbs_step_apply_banked`: Pallas
    ``grid=(T, blocks)`` on TPU, vmap-of-ref oracle elsewhere);
  * **inactive keys** take the pure-decay fast path: their per-key
    ``pending`` factor is multiplied by the tick's decay -- one vectorized
    [K] op, NO payload movement. The deferred downsample is composed into
    the key's next touch (the tick map runs with the composed factor
    ``d_eff = pending``) or into its extract view; Theorem 4.1 makes the
    composition exact in distribution: chaining downsamples C -> C' -> C''
    has the same inclusion marginals as one C -> C'' downsample, so a key's
    reservoir in a K-key bank is distributionally identical to a standalone
    sampler fed only that key's arrivals with wall-clock gaps
    (``DecaySchedule.tick(dt=...)``) -- re-verified per key in
    tests/test_bank.py.

Schemes: ``rtbs`` (bounded size + exact time bias per key) and ``ttbs``
(Alg. 1 per key). ``make_bank(scheme, num_keys=..., ...)`` is the registry
entry point, the bank-level twin of :func:`repro.core.api.make_sampler`;
decay takes the same ``lam`` scalar sugar or ``decay=DecaySchedule`` (the
schedule's bookkeeping is shared across keys -- per-key IRREGULARITY lives
entirely in ``pending``), and ``step_decayed`` accepts an external factor
(scalar, or [K] for a vmapped per-key controller). ``step(..., dt=...)``
consumes per-tick wall-clock gaps through the schedule's dt form.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core import latent as lt
from repro.core import rng, rtbs
from repro.core.api import SampleView
from repro.decay import DecaySchedule
from repro.decay import resolve as _resolve_schedule
from repro.kernels.tbs_step import ops as tbs_ops
from repro.obs.profile import scope as _scope

from . import routing


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BankState:
    """K stacked per-key reservoirs in structure-of-arrays form.

    ``items`` leaves are [K, cap, ...]. Scalar fields are [K] with
    scheme-specific meaning -- for ``rtbs``: ``nfull`` = floor(C) of the
    STORED latent, ``weight`` = stored sample weight C, ``total_weight`` =
    W as of the key's last touch; for ``ttbs``: ``nfull`` = the buffer
    count (``weight`` mirrors it as f32). ``pending`` is the per-key
    composed decay factor accumulated since the key's last touch (1.0 right
    after a touch); the key's EFFECTIVE totals are
    ``W_eff = pending * total_weight`` and, for rtbs,
    ``C_eff = min(weight, W_eff)``. ``overflow`` counts per-key items
    dropped by the routing ``bcap`` or the buffer capacity. ``dstate`` is
    the shared decay-schedule bookkeeping (None for constant-rate
    schedules).
    """

    items: Any
    nfull: jax.Array         # [K] int32
    weight: jax.Array        # [K] float32
    total_weight: jax.Array  # [K] float32
    pending: jax.Array       # [K] float32
    overflow: jax.Array      # [K] int32
    dstate: Any


@dataclasses.dataclass(frozen=True, eq=False)
class SamplerBank:
    """K per-key sampling schemes bound to their hyperparameters.

    The bank-level twin of :class:`repro.core.api.Sampler` (static closure
    bundle, identity hashing for memoization keys). Closures:

      * ``init(item_proto) -> BankState``
      * ``step(key, state, keys, payload, bcount, dt=None) -> BankState`` --
        consume one keyed batch: ``keys`` [b] int32, ``payload`` a pytree
        with leading dim b, valid prefix ``bcount``; ``dt`` (optional traced
        scalar) is the wall-clock gap this tick spans.
      * ``step_decayed(key, state, keys, payload, bcount, d)`` -- the step
        with the tick's decay factor supplied from outside (scalar or [K]:
        a per-key closed-loop controller drives exactly this).
      * ``step_stats`` / ``step_decayed_stats`` -- the same steps, returning
        ``(BankState, stats)`` where ``stats`` surfaces the tick's routing
        accounting that ``step`` computes internally: ``overflow`` (total
        items dropped by the per-key ``bcap``/capacity bounds this tick),
        ``ntouched`` (distinct arriving keys), ``invalid`` (rows with
        out-of-range key ids), ``decay`` (the applied factor, scalar or
        [K]). The manage loops use these so overflow is VISIBLE in their
        metrics dict instead of silently accumulating in
        ``BankState.overflow``.
      * ``extract(key, state, key_ids) -> SampleView`` -- realize the listed
        keys' samples, stacked: item leaves [Q, cap, ...], mask [Q, cap],
        size [Q]. Pending (deferred) decay is applied IN the view.
      * ``size(key, state, key_ids) -> [Q] int32`` -- the payload-free fast
        path; matches ``extract``'s sizes for the same key.
      * ``base_rate(state, dt=None)`` -- the tick's schedule factor (before
        any external override), for drivers that need to fill a [K] factor
        vector around a controlled key subset.
    """

    scheme: str
    num_keys: int
    cap: int
    bcap: int
    init: Callable[[Any], BankState]
    step: Callable[..., BankState]
    step_decayed: Callable[..., BankState]
    extract: Callable[[jax.Array, BankState, jax.Array], SampleView]
    size: Callable[[jax.Array, BankState, jax.Array], jax.Array]
    base_rate: Callable[..., jax.Array]
    hyper: Mapping[str, Any]
    step_stats: Callable[..., tuple] | None = None
    step_decayed_stats: Callable[..., tuple] | None = None

    def __repr__(self) -> str:
        hp = ", ".join(f"{k}={v}" for k, v in self.hyper.items())
        return f"SamplerBank({self.scheme}, K={self.num_keys}, {hp})"


_REGISTRY: dict[str, Callable[..., SamplerBank]] = {}


def register_bank(name: str):
    """Decorator: register a ``(num_keys=..., **hyper) -> SamplerBank``
    builder under ``name`` (mirrors :func:`repro.core.api.register`)."""

    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def available_bank_schemes() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_bank(scheme: str, *, num_keys: int, **hyper) -> SamplerBank:
    """Construct a registered bank scheme, e.g.
    ``make_bank("rtbs", num_keys=1_000_000, n=64, lam=0.05, bcap=32)``."""
    try:
        builder = _REGISTRY[scheme]
    except KeyError:
        raise ValueError(
            f"unknown bank scheme {scheme!r}; available: "
            f"{available_bank_schemes()}"
        ) from None
    if num_keys < 1:
        raise ValueError(f"num_keys must be >= 1; got {num_keys}")
    return builder(num_keys=num_keys, **hyper)


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------
def _stacked_items(item_proto: Any, num_keys: int, cap: int) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((num_keys, cap) + tuple(p.shape), p.dtype),
        item_proto,
    )


def _init_bank_state(item_proto: Any, num_keys: int, cap: int,
                     init_dstate) -> BankState:
    """The zeroed K-key state shared by every bank scheme."""
    return BankState(
        items=_stacked_items(item_proto, num_keys, cap),
        nfull=jnp.zeros((num_keys,), jnp.int32),
        weight=jnp.zeros((num_keys,), jnp.float32),
        total_weight=jnp.zeros((num_keys,), jnp.float32),
        pending=jnp.ones((num_keys,), jnp.float32),
        overflow=jnp.zeros((num_keys,), jnp.int32),
        dstate=init_dstate(),
    )


def _make_steps(sched_tick, advance):
    """(step, step_decayed, step_stats, step_decayed_stats) from a scheme's
    ``advance(key, state, keys, payload, bcount, d, new_dstate) -> (state,
    stats)``: ``step`` pulls the tick's factor from the shared schedule
    (optionally over a wall-clock gap ``dt``); ``step_decayed`` applies an
    external factor (scalar or [K], the controller's entry point) while the
    schedule bookkeeping still advances -- the same contract as
    :func:`repro.core.api._thread_schedule`. The ``*_stats`` twins return
    the tick's routing stats alongside (see :class:`SamplerBank`); the
    plain forms drop them, keeping the historical signature."""

    def step_stats(key, state, keys, payload, bcount, dt=None):
        d, new_dstate = sched_tick(state.dstate, dt)
        return advance(key, state, keys, payload, bcount, d, new_dstate)

    def step_decayed_stats(key, state, keys, payload, bcount, d):
        _, new_dstate = sched_tick(state.dstate, None)
        return advance(key, state, keys, payload, bcount,
                       jnp.asarray(d, jnp.float32), new_dstate)

    def step(key, state, keys, payload, bcount, dt=None):
        return step_stats(key, state, keys, payload, bcount, dt)[0]

    def step_decayed(key, state, keys, payload, bcount, d):
        return step_decayed_stats(key, state, keys, payload, bcount, d)[0]

    return step, step_decayed, step_stats, step_decayed_stats


def _check_key_ids(key_ids, num_keys: int) -> jax.Array:
    """In-range guard for extract/size key lists: traced gathers clamp
    silently, which would alias a bad id onto another tenant's reservoir --
    fail eagerly when the ids are concrete instead."""
    ids = jnp.asarray(key_ids, jnp.int32)
    try:
        lo, hi = int(ids.min()), int(ids.max())
    except jax.errors.ConcretizationTypeError:
        return jnp.clip(ids, 0, num_keys - 1)  # traced: clamp defensively
    if lo < 0 or hi >= num_keys:
        raise ValueError(
            f"key_ids must lie in [0, {num_keys}); got range [{lo}, {hi}] "
            "-- sharded banks take LOCAL ids (see shard_keyed_stream)"
        )
    return ids


def _schedule_fns(sched: DecaySchedule):
    """(init_dstate, tick, rate): the bank's shared-schedule decay source.
    Constant-rate schedules carry no state (``dstate`` stays None) so the
    common exponential bank adds nothing to the pytree."""
    if sched.static_rate is not None:
        d0 = jnp.float32(sched.static_rate)

        def tick(dstate, dt):
            return (
                d0 if dt is None else sched.factor_dt(jnp.float32(0.0), dt),
                None,
            )

        return (lambda: None), tick, (lambda dstate, dt: tick(dstate, dt)[0])

    def tick(dstate, dt):
        return sched.tick(dstate, dt)

    return sched.init, tick, (lambda dstate, dt: tick(dstate, dt)[0])


def _scatter(a: jax.Array, touched: jax.Array, v) -> jax.Array:
    """Write per-touched-key values back into a [K] column; padded rows
    (sentinel key == K) drop."""
    return a.at[touched].set(v, mode="drop")


def _fold_keys(key: jax.Array, touched: jax.Array) -> jax.Array:
    """Per-key RNG streams: fold each touched key id into the tick key --
    the same fold a standalone per-key driver would apply, which is what
    makes the bank-vs-vmap-of-single parity bit-exact."""
    return jax.vmap(lambda k_id: jax.random.fold_in(key, k_id))(touched)


def _tick_stats(r: "routing.Routing", overflow, d) -> dict:
    """The step's visible routing accounting (the ``*_stats`` closures'
    second return): per-tick totals, all scalars except ``decay`` which
    keeps the caller's scalar-or-[K] shape."""
    return {
        "overflow": jnp.asarray(overflow, jnp.int32),
        "ntouched": r.ntouched,
        "invalid": r.invalid,
        "decay": jnp.asarray(d, jnp.float32),
    }


def _route_and_gather(keys, payload, bcount, *, num_keys: int, bcap: int):
    r = routing.route(keys, bcount, num_keys=num_keys, bcap=bcap)
    sub = routing.subbatches(r, payload, bcap=bcap)
    idx = jnp.minimum(r.touched, num_keys - 1)  # clipped gather; rows drop
    return r, sub, idx


# ---------------------------------------------------------------------------
# R-TBS bank
# ---------------------------------------------------------------------------
@register_bank("rtbs")
def _make_rtbs_bank(*, num_keys: int, n: int, lam: float | None = None,
                    decay: DecaySchedule | None = None,
                    bcap: int = 64, impl: str | None = None) -> SamplerBank:
    """K independent R-TBS reservoirs (paper Alg. 2 per key): bounded size n
    and exact time bias for EVERY key, whatever its arrival pattern.

    ``bcap`` is the static per-key sub-batch capacity (routing overflow
    beyond it is dropped and counted); ``impl`` routes the banked payload
    pass (None = auto: Pallas kernel on TPU, vmap-of-ref oracle elsewhere).
    """
    sched = _resolve_schedule(lam, decay)
    cap = n + 1
    K = num_keys
    init_dstate, sched_tick, sched_rate = _schedule_fns(sched)

    def init(item_proto: Any) -> BankState:
        return _init_bank_state(item_proto, K, cap, init_dstate)

    def _advance(key, state: BankState, keys, payload, bcount, d,
                 new_dstate) -> BankState:
        # inactive-key fast path: every key's deferred factor composes the
        # tick's decay -- one [K] multiply, no payload movement
        with _scope("bank.decay"):
            pending = state.pending * d
        with _scope("bank.route"):
            r, sub, idx = _route_and_gather(keys, payload, bcount,
                                            num_keys=K, bcap=bcap)
        with _scope("bank.tick_map"):
            tkeys = _fold_keys(key, r.touched)
            d_eff = pending[idx]        # composed decay since last touch
            src, C3, w_new = jax.vmap(
                lambda kk, k0, C, W, cnt, dd: rtbs.tick_map(
                    kk, k0, C, W, cnt, dd, cap=cap, bcap=bcap, n=n
                )
            )(tkeys, state.nfull[idx], state.weight[idx],
              state.total_weight[idx], r.counts, d_eff)
        with _scope("bank.payload"):
            items_t = lt.gather(state.items, idx)  # [T, cap, ...]
            new_items_t = tbs_ops.tbs_step_apply_banked(items_t, sub, src,
                                                        impl=impl)
            items = jax.tree_util.tree_map(
                lambda a, o: a.at[r.touched].set(o, mode="drop"),
                state.items, new_items_t,
            )
        k3, _ = lt.floor_frac(C3)
        new_state = BankState(
            items=items,
            nfull=_scatter(state.nfull, r.touched, k3),
            weight=_scatter(state.weight, r.touched, C3),
            total_weight=_scatter(state.total_weight, r.touched, w_new),
            pending=_scatter(pending, r.touched, jnp.ones_like(C3)),
            overflow=state.overflow.at[r.touched].add(r.dropped, mode="drop"),
            dstate=new_dstate,
        )
        return new_state, _tick_stats(r, r.overflow, d)

    step, step_decayed, step_stats, step_decayed_stats = _make_steps(
        sched_tick, _advance
    )

    def _effective(state: BankState, idx):
        w_eff = state.pending[idx] * state.total_weight[idx]
        return jnp.minimum(state.weight[idx], w_eff)

    def extract(key, state, key_ids):
        def one(idx):
            kk = jax.random.fold_in(key, idx)
            k_ds, k_re = jax.random.split(kk)
            c_eff = _effective(state, idx)
            lat = lt.Latent(
                items=jax.tree_util.tree_map(lambda a: a[idx], state.items),
                nfull=state.nfull[idx],
                weight=state.weight[idx],
            )
            # settle the deferred decay in-view: ONE composed Thm-4.1
            # downsample C_stored -> C_eff (identity when untouched decay
            # hasn't pushed W_eff below the stored C)
            lat = lt.downsample(k_ds, lat, c_eff, max_deleted=bcap)
            mask, size = lt.realize(k_re, lat)
            return lat.items, mask, size

        items, mask, size = jax.vmap(one)(_check_key_ids(key_ids, K))
        return SampleView(items=items, mask=mask, size=size)

    def size(key, state, key_ids):
        def one(idx):
            kk = jax.random.fold_in(key, idx)
            _, k_re = jax.random.split(kk)
            k, take, _ = lt.partial_draw(k_re, _effective(state, idx))
            return k + take.astype(jnp.int32)

        return jax.vmap(one)(_check_key_ids(key_ids, K))

    hyper = {"n": n, "decay": sched, "bcap": bcap}
    if lam is not None:
        hyper["lam"] = lam
    return SamplerBank(
        scheme="rtbs", num_keys=K, cap=cap, bcap=bcap, init=init, step=step,
        step_decayed=step_decayed, extract=extract, size=size,
        base_rate=lambda state, dt=None: sched_rate(state.dstate, dt),
        hyper=hyper, step_stats=step_stats,
        step_decayed_stats=step_decayed_stats,
    )


# ---------------------------------------------------------------------------
# T-TBS bank
# ---------------------------------------------------------------------------
def _ttbs_key_map(key, count, bcount, p, q, *, cap: int, bcap: int):
    """One key's T-TBS tick (paper Alg. 1) as a slot map over (buffer,
    sub-batch) -- the EXACT draw sequence of
    :func:`repro.core.simple.ttbs_step` (same key splits, same binomials,
    same PRP), so a bank tick is bit-identical to vmapping the standalone
    step over the routed sub-batches."""
    k_ret, k_perm, k_acc, k_pick = jax.random.split(key, 4)
    m = rng.binomial(k_ret, count, p)
    perm = rng.prefix_permutation_fast(k_perm, cap, count)
    k_acc_n = rng.binomial(k_acc, bcount, q)
    picks = rng.prefix_permutation_fast(k_pick, bcap, bcount)
    j = jnp.arange(cap, dtype=jnp.int32)
    in_insert = (j >= m) & (j < m + k_acc_n)
    src = jnp.where(
        in_insert, cap + picks[jnp.clip(j - m, 0, bcap - 1)], perm[j]
    )
    new_count = jnp.minimum(m + k_acc_n, cap)
    dropped = jnp.maximum(m + k_acc_n - cap, 0)
    return src, new_count, dropped


@register_bank("ttbs")
def _make_ttbs_bank(*, num_keys: int, n: int, lam: float | None = None,
                    decay: DecaySchedule | None = None, batch_size: float,
                    cap: int | None = None, bcap: int = 64,
                    impl: str | None = None) -> SamplerBank:
    """K independent T-TBS buffers (paper Alg. 1 per key).

    Per-key retention composes exactly (Binomial thinning at rate p1 then p2
    == one thinning at p1*p2), so the lazy ``pending`` factor IS the per-key
    retention probability at next touch. The acceptance probability is
    calibrated per TICK from the base (single-gap) rate:
    ``q_t = clip(n (1 - d_t) / batch_size, 0, 1)`` with ``batch_size`` the
    key's mean arrivals per touched tick -- same parameterization as
    :func:`repro.core.api._ttbs_step_d`, including the transient-undershoot
    clip for time-varying schedules."""
    sched = _resolve_schedule(lam, decay)
    cap = 4 * n if cap is None else cap
    K = num_keys
    init_dstate, sched_tick, sched_rate = _schedule_fns(sched)

    def init(item_proto: Any) -> BankState:
        return _init_bank_state(item_proto, K, cap, init_dstate)

    def _advance(key, state, keys, payload, bcount, d, new_dstate):
        pending = state.pending * d
        q_full = jnp.clip(
            n * (1.0 - jnp.broadcast_to(jnp.asarray(d, jnp.float32), (K,)))
            / jnp.float32(batch_size),
            0.0, 1.0,
        )
        r, sub, idx = _route_and_gather(keys, payload, bcount,
                                       num_keys=K, bcap=bcap)
        tkeys = _fold_keys(key, r.touched)
        p_eff = pending[idx]             # composed retention since last touch
        src, new_count, dropped_cap = jax.vmap(
            lambda kk, c, cnt, p, q: _ttbs_key_map(kk, c, cnt, p, q,
                                                   cap=cap, bcap=bcap)
        )(tkeys, state.nfull[idx], r.counts, p_eff, q_full[idx])
        items_t = lt.gather(state.items, idx)
        new_items_t = tbs_ops.tbs_step_apply_banked(items_t, sub, src,
                                                    impl=impl)
        items = jax.tree_util.tree_map(
            lambda a, o: a.at[r.touched].set(o, mode="drop"),
            state.items, new_items_t,
        )
        w_new = p_eff * state.total_weight[idx] \
            + r.counts.astype(jnp.float32)
        new_state = BankState(
            items=items,
            nfull=_scatter(state.nfull, r.touched, new_count),
            weight=_scatter(state.weight, r.touched,
                            new_count.astype(jnp.float32)),
            total_weight=_scatter(state.total_weight, r.touched, w_new),
            pending=_scatter(pending, r.touched, jnp.ones_like(w_new)),
            overflow=state.overflow.at[r.touched].add(
                r.dropped + dropped_cap, mode="drop"
            ),
            dstate=new_dstate,
        )
        ov = r.overflow + jnp.where(
            jnp.arange(dropped_cap.shape[0]) < r.ntouched, dropped_cap, 0
        ).sum()
        return new_state, _tick_stats(r, ov, d)

    step, step_decayed, step_stats, step_decayed_stats = _make_steps(
        sched_tick, _advance
    )

    def _keep_mask(key, state, idx):
        # the T-TBS sample IS the buffer; pending retention (a composed
        # Binomial thinning, exact per-item Bernoulli at rate ``pending``)
        # settles in the view
        kk = jax.random.fold_in(key, idx)
        keep = jax.random.bernoulli(kk, state.pending[idx], (cap,))
        valid = jnp.arange(cap) < state.nfull[idx]
        return valid & (keep | (state.pending[idx] >= 1.0))

    def extract(key, state, key_ids):
        def one(idx):
            mask = _keep_mask(key, state, idx)
            items = jax.tree_util.tree_map(lambda a: a[idx], state.items)
            return items, mask, mask.sum().astype(jnp.int32)

        items, mask, size = jax.vmap(one)(_check_key_ids(key_ids, K))
        return SampleView(items=items, mask=mask, size=size)

    def size(key, state, key_ids):
        def one(idx):
            return _keep_mask(key, state, idx).sum().astype(jnp.int32)

        return jax.vmap(one)(_check_key_ids(key_ids, K))

    hyper = {"n": n, "decay": sched, "batch_size": batch_size, "cap": cap,
             "bcap": bcap}
    if lam is not None:
        hyper["lam"] = lam
    return SamplerBank(
        scheme="ttbs", num_keys=K, cap=cap, bcap=bcap, init=init, step=step,
        step_decayed=step_decayed, extract=extract, size=size,
        base_rate=lambda state, dt=None: sched_rate(state.dstate, dt),
        hyper=hyper, step_stats=step_stats,
        step_decayed_stats=step_decayed_stats,
    )
