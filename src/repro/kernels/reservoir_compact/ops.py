"""jit wrapper for reservoir compaction.

Implementation routing (``impl``): ``None`` auto-selects the compiled Pallas
kernel on TPU and the pure-jnp oracle elsewhere (the oracle is the fast CPU
path; ``"interpret"`` runs the kernel body under the Pallas interpreter for
CPU CI validation, ``"pallas"`` forces compilation).

The backend choice is resolved OUTSIDE the jit boundary and passed as a
static argument so it participates in the jit cache key. The previous
wrapper called ``jax.default_backend()`` at trace time inside a jit keyed
only on ``block``: the first call froze the interpret/compiled decision for
the process lifetime, silently running interpret-mode kernels after a
backend flip (or vice versa).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def _auto_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("block", "impl"))
def _compact(items, mask, *, block, impl):
    if impl == "ref":
        return ref.compact_ref(items, mask)
    cap, D = items.shape
    b = min(block, cap)
    pad = -cap % b
    if pad:  # kernel requires cap % block == 0; padded rows are masked out
        items = jnp.concatenate([items, jnp.zeros((pad, D), items.dtype)])
        mask = jnp.concatenate([mask, jnp.zeros((pad,), mask.dtype)])
    out, cnt = kernel.compact(
        items, mask, block=b, interpret=(impl == "interpret")
    )
    return out[:cap], cnt


def reservoir_compact(items, mask, *, block=128, impl=None):
    """items [cap, D]; mask [cap] bool -> (compacted [cap, D], count).
    Stable: surviving rows keep their relative order. ``impl`` as per the
    module docstring; any ``cap`` is accepted (padded to the block size), and
    bool / sub-int32 integer payloads are widened for the one-hot matmul and
    cast back."""
    if impl is None:
        impl = _auto_impl()
    dt = items.dtype
    wide = dt if jnp.issubdtype(dt, jnp.floating) or dt == jnp.int32 else jnp.int32
    out, cnt = _compact(items.astype(wide), mask, block=block, impl=impl)
    return out.astype(dt), cnt
