"""jit wrapper for reservoir compaction (CPU interpret fallback)."""
from __future__ import annotations

import functools

import jax

from . import kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("block",))
def reservoir_compact(items, mask, *, block=128):
    """items [cap, D]; mask [cap] bool -> (compacted [cap, D], count)."""
    return kernel.compact(items, mask, block=block, interpret=_on_cpu())
