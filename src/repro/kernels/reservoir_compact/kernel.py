"""Reservoir compaction Pallas-TPU kernel -- the paper-specific hot spot.

Every R-TBS downsample/delete pass must compact surviving reservoir items to
the buffer head (the Spark implementation's in-place RDD update, Sec. 5.2/E.2
of the paper, reborn for fixed-shape TPU buffers). The fused kernel streams
item blocks through VMEM once: per block it computes the keep-mask prefix sum
(running offset carried in SMEM-like scratch across the sequential grid) and
scatters survivors via a one-hot matmul (selection matrices are MXU work, the
TPU-native substitute for vector scatter).

Payload rows move HBM->VMEM->HBM exactly once; the selection one-hot is
[block, cap] and never leaves VMEM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(items_ref, mask_ref, out_ref, cnt_ref, off_ref, *, block, cap, nb):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        off_ref[...] = jnp.zeros_like(off_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    items = items_ref[...]                       # [block, D]
    mask = mask_ref[...][:, 0]                   # [block] int32 0/1
    excl = jnp.cumsum(mask) - mask               # exclusive prefix sum
    pos = off_ref[0, 0] + excl                   # global dest slot per row
    # one-hot selection: sel[i, j] = keep_i and pos_i == j  -> out += sel^T @ x
    jj = jax.lax.broadcasted_iota(jnp.int32, (block, cap), 1)
    sel = ((jj == pos[:, None]) & (mask[:, None] > 0)).astype(items.dtype)
    out_ref[...] += jax.lax.dot_general(
        sel, items, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype,
    )
    off_ref[0, 0] = off_ref[0, 0] + jnp.sum(mask)

    @pl.when(bi == nb - 1)
    def _emit():
        cnt_ref[0, 0] = off_ref[0, 0]


def compact(items, mask, *, block=128, interpret=False):
    """items [cap, D]; mask [cap] bool -> (compacted [cap, D], count int32).
    Surviving rows keep their relative order (stable compaction)."""
    cap, D = items.shape
    b = min(block, cap)
    assert cap % b == 0
    nb = cap // b
    mask_i = mask.astype(jnp.int32).reshape(cap, 1)

    out, cnt = pl.pallas_call(
        functools.partial(_kernel, block=b, cap=cap, nb=nb),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((b, D), lambda bi: (bi, 0)),
            pl.BlockSpec((b, 1), lambda bi: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((cap, D), lambda bi: (0, 0)),
            pl.BlockSpec((1, 1), lambda bi: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap, D), items.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.int32)],
        interpret=interpret,
    )(items, mask_i)
    return out, cnt[0, 0]
