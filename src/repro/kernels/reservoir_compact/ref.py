"""Pure-jnp oracle for reservoir compaction (stable boolean-mask compact)."""
from __future__ import annotations

import jax.numpy as jnp


def compact_ref(items, mask):
    """items [cap, D]; mask [cap] bool -> (compacted [cap, D] zero-padded,
    count). Stable: surviving rows keep their order."""
    cap, D = items.shape
    mask_i = mask.astype(jnp.int32)
    pos = jnp.cumsum(mask_i) - mask_i
    dest = jnp.where(mask, pos, cap)
    out = jnp.zeros_like(items).at[dest].add(
        items * mask_i[:, None].astype(items.dtype), mode="drop"
    )
    return out, jnp.sum(mask_i)
