"""Flash attention Pallas-TPU kernel: online softmax over [block_q, block_k]
VMEM tiles; grid = (batch*q_heads, nq, nk) with the kv axis innermost so the
f32 accumulator scratch persists across kv steps. GQA: the kv BlockSpec
index-maps q-head bh -> kv head bh // group_size. Causal and sliding-window
masking are positional; fully-masked kv tiles are skipped via @pl.when."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30  # python float: jnp scalars would be captured consts in the kernel


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, block_q, block_k, nk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos0 = qi * block_q
    kpos0 = ki * block_k
    # skip tiles that are entirely masked out (causal upper / window lower)
    run = jnp.bool_(True)
    if causal:
        run &= kpos0 <= qpos0 + block_q - 1
    if window:
        run &= kpos0 + block_k - 1 > qpos0 - window

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # [bq, hd]
        k = k_ref[0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0].astype(jnp.float32)              # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                      # [bq, bk]
        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention_bhsd(q, k, v, *, causal=True, window=0,
                         block_q=128, block_k=128, interpret=False):
    """q: [BH, S, hd]; k, v: [BKV, T, hd] with BH = BKV * group. -> [BH, S, hd]."""
    BH, S, hd = q.shape
    BKV, T, _ = k.shape
    group = BH // BKV
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0
    nq, nk = S // bq, T // bk
    scale = 1.0 / (hd ** 0.5)

    grid = (BH, nq, nk)
    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            block_q=bq, block_k=bk, nk=nk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh // group, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
