"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    """q [B,S,H,hd]; k,v [B,T,KV,hd] -> [B,S,H,hd] (f32 softmax)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, jnp.float32(-1e30))
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd)
