"""jit wrapper for the flash attention kernel ([B,S,H,hd] layout, GQA),
interpret=True on CPU hosts (kernel body executed by the Pallas interpreter)."""
from __future__ import annotations

import functools

import jax

from . import kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k")
)
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128):
    """q [B,S,H,hd]; k,v [B,T,KV,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    # GQA layout: q heads of one kv group must be adjacent per batch --
    # [B, H, ...] with H = KV * G is exactly that ordering.
    of = kernel.flash_attention_bhsd(
        qf, kf, vf, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_on_cpu(),
    )
    return of.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
