"""jit wrapper for the fused TBS-step payload pass.

Implementation routing (``impl``):

  * ``None``        -- auto: compiled Pallas kernel on TPU, pure-jnp oracle on
                       CPU/GPU (the oracle IS the fast path there; interpret
                       mode is for kernel-body validation, not throughput).
  * ``"pallas"``    -- compiled kernel (TPU).
  * ``"interpret"`` -- kernel body under the Pallas interpreter (CPU CI parity
                       tests execute the real kernel logic this way).
  * ``"ref"``       -- pure-jnp oracle.

The backend-dependent choice is resolved OUTSIDE the jit boundary and passed
as a static argument, so it is part of the jit cache key: flipping
``jax.default_backend()`` between calls re-dispatches instead of silently
reusing a stale interpret/compiled decision (the bug class fixed in
:mod:`repro.kernels.reservoir_compact.ops`). When called inside an outer jit
the choice is baked at the OUTER trace, which owns the cache-key problem.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def _auto_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("block", "impl"))
def _apply2d(items, batch, src, *, block, impl):
    if impl == "ref":
        return ref.apply_ref(items, batch, src[: items.shape[0]])
    cap, D = items.shape
    capP = src.shape[0]
    pad = -capP % min(block, max(capP, 1))
    if pad:
        src = jnp.concatenate([src, jnp.zeros((pad,), jnp.int32)])
    out = kernel.apply(
        items, batch, src, block=block, interpret=(impl == "interpret")
    )
    return out[:cap]


@functools.partial(jax.jit, static_argnames=("block", "impl"))
def _apply3d(items, batch, src, *, block, impl):
    if impl == "ref":
        return ref.apply_banked_ref(items, batch, src[:, : items.shape[1]])
    T, cap, D = items.shape
    capP = src.shape[1]
    pad = -capP % min(block, max(capP, 1))
    if pad:
        src = jnp.concatenate(
            [src, jnp.zeros((T, pad), jnp.int32)], axis=1
        )
    out = kernel.apply_banked(
        items, batch, src, block=block, interpret=(impl == "interpret")
    )
    return out[:, :cap]


def tbs_step_apply_banked(items, batch_items, src, *, block=128, impl=None):
    """Banked :func:`tbs_step_apply` (DESIGN.md Sec. 13): apply T independent
    tick slot-maps ``src[T, cap]`` to T stacked reservoirs in ONE launch.
    ``items`` leaves are [T, cap, ...] (the touched keys' reservoirs, gathered
    from the bank), ``batch_items`` leaves [T, bcap, ...] (their routed
    sub-batches). Same dtype widening and impl routing as the single-reservoir
    wrapper; ``impl="ref"`` is the vmap-of-ref parity oracle."""
    if impl is None:
        impl = _auto_impl()

    def one(leaf, bleaf):
        T, cap = leaf.shape[:2]
        dt = leaf.dtype
        wide = dt if jnp.issubdtype(dt, jnp.floating) else jnp.int32
        flat = leaf.reshape(T, cap, -1).astype(wide)
        bflat = bleaf.reshape(T, bleaf.shape[1], -1).astype(wide)
        out = _apply3d(flat, bflat, src, block=block, impl=impl)
        return out.reshape(leaf.shape).astype(dt)

    return jax.tree_util.tree_map(one, items, batch_items)


def tbs_step_apply(items, batch_items, src, *, block=128, impl=None):
    """Apply the composed tick slot-map ``src[cap]`` (values in
    [0, cap + bcap): reservoir row, or ``cap +`` batch row) to an item pytree:
    one two-source payload pass per leaf. Leaves may have any trailing shape
    (flattened to [cap, D]) and any dtype (sub-int32 ints and bools are
    widened for the MXU one-hot matmul and cast back)."""
    if impl is None:
        impl = _auto_impl()

    def one(leaf, bleaf):
        cap = leaf.shape[0]
        dt = leaf.dtype
        wide = dt if jnp.issubdtype(dt, jnp.floating) else jnp.int32
        flat = leaf.reshape(cap, -1).astype(wide)
        bflat = bleaf.reshape(bleaf.shape[0], -1).astype(wide)
        out = _apply2d(flat, bflat, src, block=block, impl=impl)
        return out.reshape(leaf.shape).astype(dt)

    return jax.tree_util.tree_map(one, items, batch_items)
