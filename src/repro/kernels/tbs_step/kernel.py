"""Fused TBS-step Pallas-TPU kernel: the whole tick's buffer rewrite as one pass.

The sampler step (decay-downsample slot map + batch insert + victim
replacement, composed by :mod:`repro.core.rtbs` into one ``src`` map) is a
two-source gather: output slot i pulls from reservoir row ``src[i]`` when
``src[i] < cap``, else from batch row ``src[i] - cap``. Both sources stay
resident in VMEM across the sequential grid; each output block builds two
one-hot selection matrices ([block, cap] / [block, bcap], never leaving VMEM)
and scatters the rows via MXU matmuls. Payload rows therefore move
HBM -> VMEM -> HBM exactly once per tick."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(src_ref, items_ref, batch_ref, out_ref, *, cap, bcap):
    block = out_ref.shape[0]
    src = src_ref[...][:, 0]                       # [block] int32
    items = items_ref[...]                         # [cap, D]
    batch = batch_ref[...]                         # [bcap, D]
    jj = jax.lax.broadcasted_iota(jnp.int32, (block, cap), 1)
    sel_i = ((jj == src[:, None]) & (src[:, None] < cap)).astype(items.dtype)
    kk = jax.lax.broadcasted_iota(jnp.int32, (block, bcap), 1)
    sel_b = ((kk == (src[:, None] - cap)) & (src[:, None] >= cap)).astype(
        batch.dtype
    )
    out_ref[...] = jax.lax.dot_general(
        sel_i, items, (((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype,
    ) + jax.lax.dot_general(
        sel_b, batch, (((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype,
    )


def _kernel_banked(src_ref, items_ref, batch_ref, out_ref, *, cap, bcap):
    # the banked body is the single-reservoir kernel with a leading
    # size-1 bank block: each (key, block) grid step rewrites one output
    # block of one touched key's reservoir from that key's two VMEM-resident
    # sources
    block = out_ref.shape[1]
    src = src_ref[...][0, :, 0]                    # [block] int32
    items = items_ref[...][0]                      # [cap, D]
    batch = batch_ref[...][0]                      # [bcap, D]
    jj = jax.lax.broadcasted_iota(jnp.int32, (block, cap), 1)
    sel_i = ((jj == src[:, None]) & (src[:, None] < cap)).astype(items.dtype)
    kk = jax.lax.broadcasted_iota(jnp.int32, (block, bcap), 1)
    sel_b = ((kk == (src[:, None] - cap)) & (src[:, None] >= cap)).astype(
        batch.dtype
    )
    out_ref[0, ...] = jax.lax.dot_general(
        sel_i, items, (((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype,
    ) + jax.lax.dot_general(
        sel_b, batch, (((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype,
    )


def apply_banked(items, batch, src, *, block=128, interpret=False):
    """The bank grid dimension (DESIGN.md Sec. 13): items [T, cap, D];
    batch [T, bcap, D]; src [T, capP] int32 (capP >= cap a multiple of
    ``block``, entries in [0, cap + bcap)) -> out [T, capP, D] with
    out[t, i] = items[t, src[t, i]] if src[t, i] < cap else
    batch[t, src[t, i] - cap]. One launch advances every touched key:
    grid = (T, capP // block) with the leading axis selecting the key row.
    Parity oracle: ``jax.vmap`` of :func:`repro.kernels.tbs_step.ref.apply_ref`
    (see ref.apply_banked_ref)."""
    T, cap, D = items.shape
    bcap = batch.shape[1]
    capP = src.shape[1]
    b = min(block, capP)
    assert capP % b == 0 and capP >= cap, (capP, cap, b)
    nb = capP // b
    src3 = src.astype(jnp.int32).reshape(T, capP, 1)

    out = pl.pallas_call(
        functools.partial(_kernel_banked, cap=cap, bcap=bcap),
        grid=(T, nb),
        in_specs=[
            pl.BlockSpec((1, b, 1), lambda t, bi: (t, bi, 0)),
            pl.BlockSpec((1, cap, D), lambda t, bi: (t, 0, 0)),
            pl.BlockSpec((1, bcap, D), lambda t, bi: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, D), lambda t, bi: (t, bi, 0)),
        out_shape=jax.ShapeDtypeStruct((T, capP, D), items.dtype),
        interpret=interpret,
    )(src3, items, batch)
    return out


def apply(items, batch, src, *, block=128, interpret=False):
    """items [cap, D]; batch [bcap, D]; src [capP] int32 (capP >= cap a
    multiple of ``block``; entries in [0, cap + bcap), rows past cap are
    wasted work only) -> out [capP, D] with out[i] = items[src[i]] if
    src[i] < cap else batch[src[i] - cap]."""
    cap, D = items.shape
    bcap = batch.shape[0]
    capP = src.shape[0]
    b = min(block, capP)
    assert capP % b == 0 and capP >= cap, (capP, cap, b)
    nb = capP // b
    src2 = src.astype(jnp.int32).reshape(capP, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, cap=cap, bcap=bcap),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((b, 1), lambda bi: (bi, 0)),
            pl.BlockSpec((cap, D), lambda bi: (0, 0)),
            pl.BlockSpec((bcap, D), lambda bi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, D), lambda bi: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((capP, D), items.dtype),
        interpret=interpret,
    )(src2, items, batch)
    return out
