"""Pure-jnp oracle for the fused TBS-step payload pass (two-source gather)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_ref(items, batch, src):
    """items [cap, D]; batch [bcap, D]; src [cap] int32 with values in
    [0, cap + bcap) -> out [cap, D] where out[i] = items[src[i]] when
    src[i] < cap else batch[src[i] - cap]."""
    cap = items.shape[0]
    bcap = batch.shape[0]
    from_batch = src >= cap
    gi = jnp.take(items, jnp.clip(src, 0, cap - 1), axis=0)
    gb = jnp.take(batch, jnp.clip(src - cap, 0, bcap - 1), axis=0)
    return jnp.where(from_batch[:, None], gb, gi)


def apply_banked_ref(items, batch, src):
    """vmap-of-:func:`apply_ref` over a leading bank axis -- THE parity
    oracle for the banked kernel's grid dimension: items [T, cap, D];
    batch [T, bcap, D]; src [T, cap] -> out [T, cap, D]."""
    return jax.vmap(apply_ref)(items, batch, src)
