"""Fused TBS-step payload pass -- the sampler hot path as ONE kernel.

A full R-TBS tick (paper Alg. 2: decay -> downsample -> batch insert /
victim replacement) is expressed by :mod:`repro.core.rtbs` as a single
slot-index map ``src[cap]`` over TWO sources -- the old reservoir
(``src < cap``) and the arriving batch (``src >= cap``) -- composed from the
per-stage maps in O(cap) integer ops. This kernel applies that map in one
VMEM-resident pass: payload rows move HBM -> VMEM -> HBM exactly once per
tick, with the row selection done as one-hot matmuls on the MXU (the
TPU-native substitute for vector gather, same idiom as
:mod:`repro.kernels.reservoir_compact`). See DESIGN.md Sec. 11.
"""
from . import ops, ref  # noqa: F401
