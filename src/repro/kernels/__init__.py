"""Pallas TPU kernels for the perf-critical compute hot spots.

  * flash_attention  -- blocked online-softmax attention (causal/SWA/GQA):
                        MXU-aligned [block_q, block_k] tiles resident in VMEM,
                        scores never touch HBM.
  * ssd_scan         -- Mamba2 SSD chunked scan: per-chunk quadratic intra
                        work + the inter-chunk state recurrence carried in a
                        VMEM scratch accumulator.
  * reservoir_compact -- the paper-specific kernel: fused keep-mask prefix-sum
                        + one-hot-matmul compaction of reservoir buffers (the
                        TPU-native replacement for Spark's in-place RDD update
                        trick; DESIGN.md Sec. 3).

Each kernel ships ``ops.py`` (jit wrapper, interpret=True fallback on CPU) and
``ref.py`` (pure-jnp oracle); tests sweep shapes/dtypes with assert_allclose.
"""
