"""Pallas TPU kernels for the perf-critical compute hot spots.

  * flash_attention  -- blocked online-softmax attention (causal/SWA/GQA):
                        MXU-aligned [block_q, block_k] tiles resident in VMEM,
                        scores never touch HBM.
  * ssd_scan         -- Mamba2 SSD chunked scan: per-chunk quadratic intra
                        work + the inter-chunk state recurrence carried in a
                        VMEM scratch accumulator.
  * reservoir_compact -- fused keep-mask prefix-sum + one-hot-matmul
                        compaction of reservoir buffers (the TPU-native
                        replacement for Spark's in-place RDD update trick;
                        DESIGN.md Sec. 3) -- wired into sample
                        materialization (``latent.realize_compact`` /
                        ``api.materialize_view``).
  * tbs_step         -- the sampler hot path: a whole R-TBS tick's composed
                        slot map applied as ONE VMEM-resident two-source
                        payload pass (reservoir + arriving batch, one-hot
                        MXU scatter; DESIGN.md Sec. 11).

Each kernel ships ``ops.py`` (backend-keyed jit wrapper: compiled Pallas on
TPU, jnp oracle off-TPU, ``impl="interpret"`` for CPU CI kernel validation)
and ``ref.py`` (pure-jnp oracle); tests sweep shapes/dtypes with
assert_allclose.
"""
