"""Mamba2 SSD chunked-scan Pallas-TPU kernel.

Grid = (batch*heads, n_chunks) with the chunk axis innermost/sequential: the
[N, P] state accumulator lives in VMEM scratch and is carried across chunks,
so the recurrence never round-trips HBM. Per chunk the kernel computes the
intra-chunk quadratic part (C.B decay-weighted scores on the MXU), the
inter-chunk contribution from the carried state, and the state update.

Layouts: x [BH, S, P]; dt [BH, S, 1]; A [H, 1]; B,C [BG, S, N] (the BlockSpec
index map sends head bh -> group (bh % H) // (H // G))."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_ref, *,
            Q, N, P, nc):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0][:, 0].astype(jnp.float32)  # [Q]
    a = a_ref[0, 0]                          # scalar A_h (negative)
    Bm = b_ref[0].astype(jnp.float32)        # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)        # [Q, N]

    la = dt * a                              # [Q] log-decay per token
    cl = jnp.cumsum(la)                      # [Q]
    # intra-chunk: scores[i,j] = (C_i.B_j) exp(cl_i - cl_j) dt_j, j <= i
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(cl[:, None] - cl[None, :])
    scores = jnp.where(ii >= jj, cb * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # [Q, P]
    # inter-chunk: y_i += exp(cl_i) * C_i . state
    state = state_ref[...]                   # [N, P]
    y += jnp.exp(cl)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # state update: state = exp(cl_last) state + sum_j exp(cl_last - cl_j) dt_j B_j x_j
    w = jnp.exp(cl[-1] - cl) * dt            # [Q]
    state_ref[...] = state * jnp.exp(cl[-1]) + jax.lax.dot_general(
        Bm * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_ref[0] = state_ref[...].astype(st_ref.dtype)


def ssd_scan_bhsp(x, dt, a, Bm, Cm, *, chunk=256, interpret=False,
                  num_heads=None, num_groups=None):
    """x [BH,S,P]; dt [BH,S,1]; a [H,1]; Bm/Cm [BG,S,N] -> (y [BH,S,P],
    final state [BH,N,P])."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    H = num_heads
    G = num_groups
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    grid = (BH, nc)
    y, st = pl.pallas_call(
        functools.partial(_kernel, Q=Q, N=N, P=P, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, Q, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh % H, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ci: ((bh // H) * G + (bh % H) // rep, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ci: ((bh // H) * G + (bh % H) // rep, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, N, P), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, Bm, Cm)
    return y, st
