"""Pure-jnp oracle for the SSD kernel: the exact per-token recurrence
    state_t = exp(dt_t * A) state_{t-1} + dt_t * B_t (x) x_t
    y_t     = C_t . state_t
(linear scan; numerically the ground truth the chunked forms must match)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, Bm, Cm):
    """x [BH,S,P]; dt [BH,S]; a [BH]; Bm/Cm [BH,S,N] (pre-broadcast per head)
    -> (y [BH,S,P], final state [BH,N,P])."""
    BH, S, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp  # [BH,P],[BH],[BH,N],[BH,N]
        da = jnp.exp(dt_t * a)     # [BH]
        state = state * da[:, None, None] + jnp.einsum(
            "b,bn,bp->bnp", dt_t, b_t, x_t
        )
        y_t = jnp.einsum("bn,bnp->bp", c_t, state)
        return state, y_t

    s0 = jnp.zeros((BH, N, P), jnp.float32)
    xs = (
        x.swapaxes(0, 1).astype(jnp.float32),
        dt.swapaxes(0, 1).astype(jnp.float32),
        Bm.swapaxes(0, 1).astype(jnp.float32),
        Cm.swapaxes(0, 1).astype(jnp.float32),
    )
    state, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), state
