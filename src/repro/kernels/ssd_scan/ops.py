"""jit wrapper for the SSD kernel (model-layout adapters + CPU interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a, Bm, Cm, *, chunk=256):
    """Model layout: x [B,S,H,P]; dt [B,S,H] (post-softplus); a [H] (<0);
    Bm/Cm [B,S,G,N] -> (y [B,S,H,P], state [B,H,N,P])."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S, 1)
    af = a.reshape(H, 1).astype(jnp.float32)
    Bf = Bm.transpose(0, 2, 1, 3).reshape(B * G, S, N)
    Cf = Cm.transpose(0, 2, 1, 3).reshape(B * G, S, N)
    y, st = kernel.ssd_scan_bhsp(
        xf, dtf, af, Bf, Cf, chunk=chunk, interpret=_on_cpu(),
        num_heads=H, num_groups=G,
    )
    return (
        y.reshape(B, H, S, P).transpose(0, 2, 1, 3),
        st.reshape(B, H, N, P),
    )
