"""Decay schedules: arbitrary time-decay for the TBS family (DESIGN.md Sec. 12).

Every scheme in :mod:`repro.core` decays the sample's total weight once per
tick. Until this subsystem existed the decay was a single scalar exponential
``lam`` frozen at sampler construction; the journal extension of the source
paper generalizes R-TBS to arbitrary decay functions, and the time-decay
literature (PAPERS.md: "Learning-Augmented Moment Estimation on Time-Decay
Models") treats polynomial decay as a first-class citizen. This module is the
repo's representation of that family:

A :class:`DecaySchedule` produces a *per-tick multiplicative decay factor*
``d_t in [0, 1]`` plus the bookkeeping state needed to compute it.  Applying
``W <- d_t * W + B_t`` every tick gives item ``i`` (arriving at tick ``t_i``)
the weight

    w_t(i) = D_t / D_{t_i},     D_t = prod_{s <= t} d_s,

i.e. exactly the family of decay functions expressible as a ratio of one
fixed cumulative sequence.  Exponential decay (``d_t = e^{-lam}`` constant)
is the time-invariant member -- weight depends only on *age* -- and remains
the algebra all of the paper's theorems are stated in; the other instances
trade that invariance for different robustness/adaptivity profiles:

  * :func:`exponential` -- the paper's eq. (1); ``static_rate`` is set, so
    samplers built from it carry NO extra state and trace identically to the
    scalar-``lam`` sugar (bit-identity asserted in tests/test_decay.py).
  * :func:`polynomial`  -- power-law in arrival time: ``w_t(i) =
    ((t_i + t0) / (t + t0))**beta``.  Forgetting slows as the stream ages
    (d_t -> 1): maximally robust, minimally adaptive.
  * :func:`piecewise`   -- exponential with a tick-indexed rate table
    (operator-planned regime changes).
  * :func:`from_callable` -- any jit-traceable ``t -> d_t``.

Schedules follow the same closure discipline as
:class:`repro.core.api.Sampler`: the schedule object is static (identity
hash, safe to close over in jitted code), only ``init()``'s return value is
a pytree.  Closed-loop *adaptive* decay -- where d_t is driven by the
prequential loss instead of a fixed schedule -- lives in
:mod:`repro.decay.adaptive` and is threaded through the manage loop, not
through the sampler state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecayedState:
    """Sampler state wrapped with its schedule's bookkeeping.

    Used by :mod:`repro.core.api` for schedules WITHOUT a ``static_rate``:
    ``inner`` is the scheme's own state pytree, ``dstate`` the schedule
    state (typically a tick counter).  Static schedules (exponential) keep
    the bare inner state, so the scalar-``lam`` sugar stays bit-identical.
    """

    dstate: Any
    inner: Any


@dataclasses.dataclass(frozen=True, eq=False)
class DecaySchedule:
    """A decay function in per-tick multiplicative form.

    ``init()`` returns the schedule state (a pytree, scan/vmap/shard_map
    safe); ``rate(dstate)`` is THIS tick's factor ``d_t`` (f32 scalar in
    [0, 1], consumed before the sampler step); ``step(dstate)`` advances the
    state by one tick.  ``static_rate`` is set iff ``rate`` is a constant
    independent of ``dstate`` -- consumers may then skip carrying the state
    entirely (the exponential fast path).  ``eq=False`` keeps identity
    hashing so schedules work inside memoization keys exactly like Samplers
    and ModelAdapters.

    Irregular arrivals (per-tick ``dt``): ``tick(dstate, dt=...)`` consumes a
    wall-clock gap instead of one unit tick.  ``rate_dt(dstate, dt)``, when a
    schedule defines it, is the EXACT composed factor over the gap --
    exponential (``e^{-lam dt}``, identical to ``d^dt``) and polynomial (the
    telescoping ratio closes over any real gap) are exact; schedules without
    it fall back to ``rate(dstate) ** dt``, i.e. the current rate held flat
    across the gap (exact for any constant-rate schedule, a documented
    approximation for piecewise/from_callable, whose rate tables are indexed
    by tick count, not wall-clock).  ``step_dt`` advances the bookkeeping by
    the gap EXACTLY: counter schedules carry elapsed time as f32, so
    repeated sub-unit gaps accumulate instead of rounding away (integer
    ticks stay integer-exact below 2^24; tick-table lookups floor the
    counter).
    """

    name: str
    init: Callable[[], Any]
    rate: Callable[[Any], jax.Array]
    step: Callable[[Any], Any]
    hyper: Mapping[str, Any]
    static_rate: float | None = None
    rate_dt: Callable[[Any, jax.Array], jax.Array] | None = None
    step_dt: Callable[[Any, jax.Array], Any] | None = None

    def factor_dt(self, dstate, dt) -> jax.Array:
        """The composed decay factor over a gap of ``dt`` time units from the
        current state (see class docstring for exactness per schedule)."""
        dt = jnp.asarray(dt, jnp.float32)
        if self.rate_dt is not None:
            return jnp.clip(
                jnp.asarray(self.rate_dt(dstate, dt), jnp.float32), 0.0, 1.0
            )
        return self.rate(dstate) ** dt

    def advance_dt(self, dstate, dt) -> Any:
        """Advance the bookkeeping state by a gap of ``dt`` time units
        (one plain ``step`` when the schedule has no native dt advance)."""
        if self.step_dt is not None:
            return self.step_dt(dstate, jnp.asarray(dt, jnp.float32))
        return self.step(dstate)

    def tick(self, dstate, dt=None) -> tuple[jax.Array, Any]:
        """Convenience: ``(d_t, advanced state)`` in one call. With ``dt``
        the factor covers the whole gap (ROADMAP decay follow-up (b):
        wall-clock gaps, not just tick indices)."""
        if dt is None:
            return self.rate(dstate), self.step(dstate)
        return self.factor_dt(dstate, dt), self.advance_dt(dstate, dt)

    def __repr__(self) -> str:
        hp = ", ".join(f"{k}={v}" for k, v in self.hyper.items())
        return f"{self.name}({hp})"


def _counter_schedule(name: str, rate_of_t: Callable[[jax.Array], jax.Array],
                      hyper: Mapping[str, Any],
                      static_rate: float | None = None,
                      rate_dt: Callable[[Any, jax.Array], jax.Array] | None = None,
                      ) -> DecaySchedule:
    """Schedules whose only state is the elapsed-time counter t (f32,
    starts 0; advances by 1 per unit tick, by ``dt`` exactly under
    irregular arrivals -- f32 is integer-exact below 2^24 ticks)."""
    return DecaySchedule(
        name=name,
        init=lambda: jnp.float32(0.0),
        rate=lambda t: jnp.clip(
            jnp.asarray(rate_of_t(t), jnp.float32), 0.0, 1.0
        ),
        step=lambda t: t + 1.0,
        hyper=hyper,
        static_rate=static_rate,
        rate_dt=rate_dt,
        step_dt=lambda t, dt: t + dt,
    )


def exponential(lam: float) -> DecaySchedule:
    """The paper's exponential decay: ``d_t = e^{-lam}`` for every tick.

    ``static_rate`` is set, so samplers built from this schedule carry no
    schedule state and ``make_sampler(scheme, lam=...)`` is literally sugar
    for ``make_sampler(scheme, decay=exponential(lam))`` (bit-identical).
    """
    if lam < 0:
        raise ValueError(f"exponential decay needs lam >= 0; got {lam}")
    d = math.exp(-float(lam))
    return _counter_schedule(
        "exponential", lambda t: jnp.float32(d), {"lam": float(lam)},
        static_rate=d,
        # exact for any real gap: e^{-lam dt} (== d^dt, age-invariant)
        rate_dt=lambda t, dt: jnp.exp(jnp.float32(-lam) * dt),
    )


def polynomial(beta: float, *, t0: float = 1.0) -> DecaySchedule:
    """Power-law (time-decay-model) weights: ``w_t(i) = ((t_i + t0) /
    (t + t0)) ** beta`` via the telescoping per-tick factor
    ``d_t = ((t - 1 + t0) / (t + t0)) ** beta``.

    Unlike exponential decay the forgetting rate is not age-invariant:
    ``d_t -> 1`` as the stream ages, so an ever-growing fraction of history
    is retained -- the robust end of the robustness/adaptivity dial
    (DESIGN.md Sec. 12). ``t0 > 0`` offsets the pole at the stream start
    (at ``t = 0`` the factor multiplies an empty sample either way).
    """
    if beta < 0:
        raise ValueError(f"polynomial decay needs beta >= 0; got {beta}")
    if t0 <= 0:
        raise ValueError(f"polynomial decay needs t0 > 0; got {t0}")

    def rate(t):
        tf = jnp.asarray(t, jnp.float32)
        return (jnp.maximum(tf - 1.0 + t0, 0.0) / (tf + t0)) ** beta

    def rate_dt(t, dt):
        # the telescoping ratio closes over any real gap: the factor from
        # counter t covering dt time units is ((t-1+t0)/(t-1+dt+t0))^beta,
        # exactly prod of the dt unit factors when dt is integral
        tf = jnp.asarray(t, jnp.float32)
        return (
            jnp.maximum(tf - 1.0 + t0, 0.0)
            / jnp.maximum(tf - 1.0 + dt + t0, 1e-30)
        ) ** beta

    return _counter_schedule(
        "polynomial", rate, {"beta": float(beta), "t0": float(t0)},
        rate_dt=rate_dt,
    )


def piecewise(boundaries: tuple[int, ...], lams: tuple[float, ...]) -> DecaySchedule:
    """Exponential decay with a tick-indexed rate table: rate ``lams[k]``
    applies on ticks in ``[boundaries[k-1], boundaries[k])`` (boundaries
    strictly increasing; ``len(lams) == len(boundaries) + 1``)."""
    boundaries = tuple(int(b) for b in boundaries)
    lams = tuple(float(v) for v in lams)
    if len(lams) != len(boundaries) + 1:
        raise ValueError(
            f"piecewise needs len(lams) == len(boundaries) + 1; got "
            f"{len(lams)} lams, {len(boundaries)} boundaries"
        )
    if any(b2 <= b1 for b1, b2 in zip(boundaries, boundaries[1:])):
        raise ValueError(f"boundaries must be strictly increasing: {boundaries}")
    if any(v < 0 for v in lams):
        raise ValueError(f"piecewise lams must be >= 0: {lams}")
    bnd = jnp.asarray(boundaries, jnp.int32)
    dec = jnp.asarray([math.exp(-v) for v in lams], jnp.float32)

    def rate(t):
        seg = jnp.searchsorted(bnd, jnp.asarray(t, jnp.int32), side="right")
        return dec[seg]

    return _counter_schedule(
        "piecewise", rate, {"boundaries": boundaries, "lams": lams},
        static_rate=(math.exp(-lams[0]) if not boundaries else None),
    )


def from_callable(fn: Callable[[jax.Array], jax.Array], *,
                  name: str = "callable", **hyper) -> DecaySchedule:
    """Arbitrary decay: ``fn(t) -> d_t`` with ``t`` the (traced) f32
    ELAPSED TIME -- integer-valued under plain unit ticks, fractional when
    driven with wall-clock ``dt`` gaps; cast with
    ``t.astype(jnp.int32)`` for tick-table lookups (as :func:`piecewise`
    does).  ``fn`` must be jit-traceable and return a factor in [0, 1]
    (clipped defensively); for a decay *rate* function ``lam(t)`` pass
    ``lambda t: jnp.exp(-lam(t))``."""
    return _counter_schedule(name, fn, dict(hyper))


def resolve(lam: float | None = None,
            decay: DecaySchedule | None = None) -> DecaySchedule:
    """The ``(lam=, decay=)`` sugar resolver used by the sampler registry:
    exactly one of the two must be given; a scalar ``lam`` means
    :func:`exponential`."""
    if (lam is None) == (decay is None):
        raise ValueError(
            "pass exactly one of lam= (scalar exponential sugar) or decay= "
            f"(a DecaySchedule); got lam={lam!r}, decay={decay!r}"
        )
    if decay is None:
        return exponential(lam)
    if not isinstance(decay, DecaySchedule):
        raise TypeError(
            f"decay= must be a repro.decay.DecaySchedule (see "
            f"repro.decay.exponential/polynomial/piecewise/from_callable); "
            f"got {type(decay).__name__} -- for a scalar rate use lam="
        )
    return decay


def decay_profile(schedule: DecaySchedule, T: int) -> jax.Array:
    """The first ``T`` factors ``[d_0, ..., d_{T-1}]`` of a schedule --
    the analytic hook for tests and benchmarks (cumulative products of this
    give every item weight ``w_t(i) = D_t / D_{t_i}``)."""

    def body(ds, _):
        d, ds = schedule.tick(ds)
        return ds, d

    _, ds = jax.lax.scan(body, schedule.init(), None, length=T)
    return ds
