"""repro.decay -- arbitrary decay schedules + closed-loop adaptive decay.

Generalizes the TBS family's frozen scalar-exponential ``lam`` to the full
per-tick multiplicative-decay family (exponential / polynomial power-law /
piecewise / arbitrary callable), and adds a prequential-loss-driven
controller that moves the decay rate online inside the jitted manage loop.
See DESIGN.md Sec. 12; threading points are ``make_sampler(..., decay=...)``
(:mod:`repro.core.api`) and ``make_run_loop(..., controller=...)``
(:mod:`repro.manage.loop`).
"""
from .adaptive import (  # noqa: F401
    AdaptiveDecay,
    ControllerState,
    loss_ratio,
)
from .schedules import (  # noqa: F401
    DecayedState,
    DecaySchedule,
    decay_profile,
    exponential,
    from_callable,
    piecewise,
    polynomial,
    resolve,
)
