"""Closed-loop adaptive decay: drive lambda from the prequential loss
(DESIGN.md Sec. 12).

The decay rate is THE robustness-vs-adaptivity dial of the source paper
(Sec. 3): large lambda forgets fast (quick recovery after drift, small
steady-state sample), small lambda remembers (big sample, slow recovery).
A fixed schedule must pick one point on that dial for the whole stream; the
controller here moves along it online, inside the jitted manage loop, using
the only signal the loop already produces every tick -- the prequential
metric (model evaluated on each batch BEFORE seeing it).

Heuristic (loss-ratio drift detector, the classic fast/slow-EMA form):

    fast <- (1 - a_f) fast + a_f loss_t          (short horizon)
    slow <- (1 - a_s) slow + a_s loss_t          (long horizon,  a_s < a_f)
    on retrain ticks:
        e = log(fast / slow)
        if e > fire and not refractory:  loglam <- log lam_max   # PULSE
        else:                            loglam <- clip(loglam
                                             + gain_down * dead(e) - relax,
                                             [log lam_min, log lam_max])

where ``dead(e) = min(e + deadband, 0) - deadband`` keeps only the
below-band part of a falling ratio.  The shape is detect-and-pulse rather
than proportional control, for reasons the failure modes dictate:

  * **Pulse, not increments.** Drift announces itself as a few ticks of
    elevated loss-ratio before retraining absorbs the signal; an
    incremental controller must win the spike in those ticks or not at
    all. Jumping straight to ``lam_max`` front-loads the flush where it is
    cheapest (the stale pool decays by e^{-lam} per tick from the first
    pulse tick).
  * **Refractory window.** The pulse itself raises the loss -- flushing
    shrinks the sample and the shrunken sample scores worse -- so for
    ``cooldown`` adjustments after a pulse the detector is disarmed and
    only annealing runs.  Without this the controller chases its own
    damage (loss up -> lambda up -> sample down -> loss up) and pins
    lambda at lam_max.  Drift that genuinely persists past the window
    fires the next pulse.
  * **Relaxation.** ``relax`` leaks log-lambda toward ``lam_min``
    whenever no pulse is firing.  A ratio detector sees *transients*, not
    levels: after the post-pulse loss plateaus, fast == slow, and without
    the leak lambda would park wherever the pulse left it (the stuck-high
    failure mode).  Elevated decay is only ever justified by an active
    drift signal, so absent one the controller always drifts back to the
    robust end -- maximum sample -- which is also why a stationary stream
    converges to lam_min instead of chattering.

Contract (mirrors :class:`repro.decay.DecaySchedule`, plus a feedback input):

  * ``init() -> cstate``                 controller state pytree
  * ``rate(cstate) -> d_t``              this tick's multiplicative decay
  * ``observe(cstate, loss, adjust)``    fold in one prequential loss sample;
                                         ``adjust`` (bool, traced or static)
                                         gates the lambda update -- the manage
                                         loop passes its retrain-tick flag, so
                                         the adjustment cadence matches the
                                         cadence at which the loss can actually
                                         respond to a lambda change.

``observe`` ignores non-finite losses (empty ticks report NaN) and runs its
first ``warmup`` observations in estimate-only mode.  All three closures are
jit/scan/vmap-safe with fixed shapes; the controller object itself is static
and hashes by identity (memoization keys, like Sampler/ModelAdapter).
Threading through the loop -- ``make_run_loop(..., controller=...)`` and the
sharded twin -- lives in :mod:`repro.manage.loop`; the sampler side needs
only the ``step_decayed`` closure every decay-capable scheme exposes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ControllerState:
    """Loop-carried state of the loss-ratio controller."""

    loglam: jax.Array   # f32, log of the current decay rate lambda
    fast: jax.Array     # f32, short-horizon EMA of the prequential loss
    slow: jax.Array     # f32, long-horizon EMA of the prequential loss
    seen: jax.Array     # int32, finite losses observed so far
    hold: jax.Array     # int32, refractory adjustments left (no up-steps)

    @property
    def lam(self) -> jax.Array:
        return jnp.exp(self.loglam)


@dataclasses.dataclass(frozen=True, eq=False)
class AdaptiveDecay:
    """A closed-loop decay controller; see the module docstring for the
    contract and :func:`loss_ratio` for the standard instance."""

    name: str
    init: Callable[[], ControllerState]
    rate: Callable[[ControllerState], jax.Array]
    observe: Callable[[ControllerState, jax.Array, jax.Array], ControllerState]
    hyper: Mapping[str, Any]
    # optional telemetry gauge extractor (repro.obs, DESIGN.md Sec. 14):
    # ``stats(cstate) -> {"lam", "hold", "pulse", ...}`` -- jit-safe scalar
    # columns for the drained tick records. Kept OUT of ControllerState so
    # checkpointed controller pytrees are unchanged.
    stats: Callable[[ControllerState], Mapping[str, jax.Array]] | None = None

    def __repr__(self) -> str:
        hp = ", ".join(f"{k}={v}" for k, v in self.hyper.items())
        return f"{self.name}({hp})"


def loss_ratio(*, lam0: float, lam_min: float, lam_max: float,
               fast_alpha: float = 0.5, slow_alpha: float = 0.05,
               fire: float = 0.25, gain_down: float = 1.0,
               relax: float = 0.3, cooldown: int = 8,
               deadband: float = 0.05, warmup: int = 3) -> AdaptiveDecay:
    """The fast/slow-EMA loss-ratio controller (module docstring).

    ``lam0`` is the starting rate, ``[lam_min, lam_max]`` the clip range
    (choose lam_min for the desired steady-state sample size via
    ``E W = b / (1 - e^{-lam})``, lam_max for the desired flush speed).
    ``fire`` is the log-ratio detection threshold that triggers a pulse to
    lam_max, ``cooldown`` the refractory window after one, ``gain_down``
    scales the extra anneal step on a falling ratio and ``relax`` the
    unconditional leak toward lam_min (see the module docstring for why
    each exists); ``deadband`` is the ignored |log fast/slow| band on the
    anneal side, ``warmup`` the number of finite losses consumed before
    any adjustment (the EMAs start AT the first loss).
    """
    if not 0 < lam_min <= lam0 <= lam_max:
        raise ValueError(
            f"need 0 < lam_min <= lam0 <= lam_max; got "
            f"lam_min={lam_min}, lam0={lam0}, lam_max={lam_max}"
        )
    if not 0 < slow_alpha <= fast_alpha <= 1:
        raise ValueError(
            f"need 0 < slow_alpha <= fast_alpha <= 1; got "
            f"slow_alpha={slow_alpha}, fast_alpha={fast_alpha}"
        )
    lo, hi = math.log(lam_min), math.log(lam_max)

    def init() -> ControllerState:
        return ControllerState(
            loglam=jnp.float32(math.log(lam0)),
            fast=jnp.float32(0.0),
            slow=jnp.float32(0.0),
            seen=jnp.int32(0),
            hold=jnp.int32(0),
        )

    def rate(c: ControllerState) -> jax.Array:
        return jnp.exp(-jnp.exp(c.loglam))

    def observe(c: ControllerState, loss, adjust) -> ControllerState:
        loss = jnp.asarray(loss, jnp.float32)
        ok = jnp.isfinite(loss)
        loss = jnp.where(ok, loss, 0.0)
        first = c.seen == 0
        fast = jnp.where(first, loss, (1 - fast_alpha) * c.fast + fast_alpha * loss)
        slow = jnp.where(first, loss, (1 - slow_alpha) * c.slow + slow_alpha * loss)
        fast = jnp.where(ok, fast, c.fast)
        slow = jnp.where(ok, slow, c.slow)
        seen = c.seen + ok.astype(jnp.int32)

        err = jnp.log(jnp.maximum(fast, 1e-12) / jnp.maximum(slow, 1e-12))
        do = jnp.asarray(adjust) & ok & (seen >= warmup)
        pulse = do & (err > fire) & (c.hold == 0)
        # anneal side: the below-deadband part of a falling ratio, plus the
        # unconditional relax leak
        dead = jnp.minimum(err + deadband, 0.0)
        annealed = jnp.clip(c.loglam + gain_down * dead - relax, lo, hi)
        loglam = jnp.where(
            pulse, jnp.float32(hi), jnp.where(do, annealed, c.loglam)
        )
        hold = jnp.where(
            do,
            jnp.where(pulse, jnp.int32(cooldown),
                      jnp.maximum(c.hold - 1, 0)),
            c.hold,
        )
        return ControllerState(loglam=loglam, fast=fast, slow=slow,
                               seen=seen, hold=hold)

    def stats(c: ControllerState) -> dict:
        # pulse detection is derivable, not stored: observe() sets the
        # refractory counter to exactly ``cooldown`` ONLY on a pulse tick
        # (otherwise it decrements toward 0), so hold == cooldown flags the
        # pulse without touching the checkpointed ControllerState layout
        return {
            "lam": jnp.exp(c.loglam),
            "hold": c.hold,
            "pulse": (cooldown > 0) & (c.hold == cooldown),
        }

    return AdaptiveDecay(
        name="loss_ratio",
        init=init,
        rate=rate,
        observe=observe,
        stats=stats,
        hyper={"lam0": lam0, "lam_min": lam_min, "lam_max": lam_max,
               "fast_alpha": fast_alpha, "slow_alpha": slow_alpha,
               "fire": fire, "gain_down": gain_down, "relax": relax,
               "cooldown": cooldown, "deadband": deadband,
               "warmup": warmup},
    )
