"""repro.manage -- the paper's online model-management loop as a subsystem.

Wires :mod:`repro.data.streams` -> a :class:`repro.core.api.Sampler` ->
periodic retraining -> prequential eval in one compiled ``lax.scan``
(:mod:`repro.manage.loop`), with model adapters for the paper's applications
and for gradient-trained zoo models (:mod:`repro.manage.models`).
See DESIGN.md Sec. 8 for the architecture. Keyed multi-tenant banks run the
same loop over K per-key samples (:mod:`repro.manage.bank_loop`,
DESIGN.md Sec. 13).
"""
from .bank_loop import (  # noqa: F401
    keyed_item_proto,
    make_bank_run_loop,
    make_sharded_bank_loop,
    pooled_view,
    shard_keyed_stream,
)
from .loop import (  # noqa: F401
    init_sharded_state,
    make_manage_step,
    make_run_farm,
    make_run_loop,
    make_sharded_manage_step,
    make_sharded_resume_loop,
    make_sharded_run_farm,
    make_sharded_run_loop,
    materialize_stream,
    run_farm,
    run_loop,
    shard_stream,
    tick_keys,
)
from .models import (  # noqa: F401
    ModelAdapter,
    available_models,
    make_model,
    make_sgd_adapter,
)
