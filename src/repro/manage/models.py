"""Model adapters for the online model-management loop (DESIGN.md Sec. 8).

A :class:`ModelAdapter` is the model-side counterpart of
:class:`repro.core.api.Sampler`: three jit/scan/vmap-safe closures with all
shapes and hyperparameters baked in,

  * ``init()``                          -> params pytree (fixed shapes)
  * ``fit(key, params, view)``          -> params retrained on a realized
                                           :class:`~repro.core.api.SampleView`
  * ``evaluate(params, batch, bcount)`` -> scalar f32 metric on the NEXT
                                           arriving batch (prequential eval:
                                           lower is better for every adapter)

Closed-form adapters (the paper's Sec. 6 applications, from
:mod:`repro.models.simple_ml`):

  ===========  ==========================  ===========================
  name         model                       metric
  ===========  ==========================  ===========================
  linreg       least-squares regression    mean squared error
  naive_bayes  multinomial NB              misclassification fraction
  knn          k-nearest-neighbour         misclassification fraction
  ===========  ==========================  ===========================

plus :func:`make_sgd_adapter`, which wraps any gradient-trained model api
(:func:`repro.train.steps.make_train_step`) so LMs from the zoo run in the
same loop: ``fit`` performs ``retrain_steps`` SGD steps on minibatches
resampled from the sample view.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core.api import SampleView
from repro.models import simple_ml


@dataclasses.dataclass(frozen=True, eq=False)
class ModelAdapter:
    """A model bound to its shapes; see module docstring for the contract.

    ``eq=False`` keeps identity hashing so adapters work as cache keys (the
    manage loop memoizes its compiled programs on (sampler, model, ...))."""

    name: str
    init: Callable[[], Any]
    fit: Callable[[jax.Array, Any, SampleView], Any]
    evaluate: Callable[[Any, Any, jax.Array], jax.Array]
    hyper: Mapping[str, Any]


_REGISTRY: dict[str, Callable[..., ModelAdapter]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def available_models() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_model(name: str, **hyper) -> ModelAdapter:
    """Construct a registered adapter, e.g. ``make_model("linreg", dim=2)``."""
    try:
        builder = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown model {name!r}; available: {available_models()}"
        ) from None
    return builder(**hyper)


def _prefix_mean(values: jax.Array, bcount: jax.Array) -> jax.Array:
    """Mean of values[:bcount] (fixed-shape: mask + safe divide); NaN for an
    empty tick, so zero-size batches can't masquerade as perfect scores."""
    n = values.shape[0]
    w = (jnp.arange(n) < bcount).astype(jnp.float32)
    mean = jnp.sum(values * w) / jnp.maximum(bcount.astype(jnp.float32), 1.0)
    return jnp.where(bcount > 0, mean, jnp.float32(jnp.nan))


@register("linreg")
def _make_linreg(*, dim: int = 2) -> ModelAdapter:
    """Least-squares regression (paper Sec. 6.3). Items: {"x": [dim], "y": []}."""

    def fit(key, params, view: SampleView):
        del key, params
        return simple_ml.linreg_fit(view.items["x"], view.items["y"], view.mask)

    def evaluate(params, batch, bcount):
        pred = simple_ml.linreg_predict(params, batch["x"])
        return _prefix_mean((pred - batch["y"]) ** 2, bcount)

    return ModelAdapter(
        name="linreg",
        init=lambda: jnp.zeros((dim + 1,), jnp.float32),
        fit=fit,
        evaluate=evaluate,
        hyper={"dim": dim},
    )


@register("naive_bayes")
def _make_naive_bayes(*, vocab: int, num_classes: int = 2) -> ModelAdapter:
    """Multinomial NB (paper Sec. 6.4). Items: {"x": [vocab] counts, "y": []}."""

    def fit(key, params, view: SampleView):
        del key, params
        return simple_ml.nb_fit(
            view.items["x"], view.items["y"], view.mask, num_classes=num_classes
        )

    def evaluate(params, batch, bcount):
        pred = simple_ml.nb_predict(params, batch["x"])
        return _prefix_mean((pred != batch["y"]).astype(jnp.float32), bcount)

    return ModelAdapter(
        name="naive_bayes",
        init=lambda: (
            jnp.zeros((num_classes,), jnp.float32),
            jnp.zeros((num_classes, vocab), jnp.float32),
        ),
        fit=fit,
        evaluate=evaluate,
        hyper={"vocab": vocab, "num_classes": num_classes},
    )


@register("knn")
def _make_knn(*, cap: int, dim: int = 2, k: int = 7,
              num_classes: int = 100) -> ModelAdapter:
    """kNN classification (paper Sec. 6.2). Nonparametric: "params" ARE the
    stored sample (x, y, valid), so ``cap`` must match the sampler's buffer
    capacity (n for brs/sw, n+1 for rtbs, the configured cap for t/b-tbs)."""

    def fit(key, params, view: SampleView):
        del key, params
        return {"x": view.items["x"], "y": view.items["y"], "valid": view.mask}

    def evaluate(params, batch, bcount):
        pred = simple_ml.knn_predict(
            params["x"], params["y"], params["valid"], batch["x"],
            k=k, num_classes=num_classes,
        )
        return _prefix_mean((pred != batch["y"]).astype(jnp.float32), bcount)

    return ModelAdapter(
        name="knn",
        init=lambda: {
            "x": jnp.zeros((cap, dim), jnp.float32),
            "y": jnp.zeros((cap,), jnp.int32),
            "valid": jnp.zeros((cap,), bool),
        },
        fit=fit,
        evaluate=evaluate,
        hyper={"cap": cap, "dim": dim, "k": k, "num_classes": num_classes},
    )


def make_sgd_adapter(*, init_params: Callable[[], Any],
                     train_step: Callable[[Any, Any, Any], tuple],
                     init_opt_state: Callable[[Any], Any],
                     loss: Callable[[Any, Any], jax.Array],
                     batch_field: str,
                     train_batch: int,
                     retrain_steps: int,
                     row_loss: Callable[[Any, Any], jax.Array] | None = None,
                     name: str = "sgd") -> ModelAdapter:
    """Adapter for gradient-trained models (the LM path of the paper's loop).

    ``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
    is a compiled step from :func:`repro.train.steps.make_train_step`;
    ``loss(params, batch) -> scalar`` is the prequential objective. ``fit``
    draws ``retrain_steps`` minibatches of ``train_batch`` rows from the
    sample view (with replacement, proportional to the membership mask) and
    runs one train step on each -- a fixed trip count, so the whole adapter
    stays scan-safe.

    ``evaluate`` caveat: the scalar ``loss`` averages over ALL rows of the
    eval batch, so with the default ``row_loss=None`` every row must be
    valid -- drivers must not zero-pad eval batches (the sharded loop's
    ``shard_stream`` pads per-shard segments whenever |B_t| is not a multiple
    of the shard count; ``launch/train.py`` rounds the tick batch up
    accordingly). Pass ``row_loss(params, batch) -> [rows]`` to get a
    bcount-masked prefix mean instead (same convention as the closed-form
    adapters), which makes padding harmless.
    """

    def init():
        params = init_params()
        return {"params": params, "opt": init_opt_state(params)}

    def fit(key, state, view: SampleView):
        m = view.mask.astype(jnp.float32)
        probs = m / jnp.maximum(m.sum(), 1.0)

        def body(i, carry):
            state, key = carry
            key, k_sel = jax.random.split(key)
            sel = jax.random.choice(
                k_sel, probs.shape[0], shape=(train_batch,), p=probs
            )
            mb = jax.tree_util.tree_map(lambda a: a[sel], view.items)
            params, opt, _ = train_step(
                state["params"], state["opt"], {batch_field: mb}
            )
            return {"params": params, "opt": opt}, key

        def do_fit():
            out, _ = jax.lax.fori_loop(0, retrain_steps, body, (state, key))
            return out

        # empty-sample guard: nothing to train on yet
        return jax.lax.cond(view.size > 0, do_fit, lambda: state)

    if row_loss is None:
        def evaluate(state, batch, bcount):
            del bcount  # scalar loss: caller guarantees no padded rows
            return loss(state["params"], {batch_field: batch})
    else:
        def evaluate(state, batch, bcount):
            return _prefix_mean(
                row_loss(state["params"], {batch_field: batch}), bcount
            )

    return ModelAdapter(
        name=name,
        init=init,
        fit=fit,
        evaluate=evaluate,
        hyper={"train_batch": train_batch, "retrain_steps": retrain_steps,
               "batch_field": batch_field},
    )
