"""The paper's online model-management loop, fused into one compiled program.

This is the connective tissue the headline claim needs (paper Sec. 1, Fig. 2):
maintain a time-biased sample over the stream, periodically retrain a model on
the realized sample, and evaluate/serve the freshest model -- here as a single
``lax.scan`` over stream batches so the whole loop compiles once and never
leaves the device (DESIGN.md Sec. 8):

    for each tick t (scanned):
      1. metric_t = model.evaluate(params, B_t)     # prequential: eval BEFORE
      2. state    = sampler.step(key_t, state, B_t) # the model/sampler see B_t
      3. if (t+1) % retrain_every == 0:
           params = model.fit(key_t', params, sampler.extract(key_t'', state))

Entry points:
  * :func:`make_run_loop`  -- compile the loop once for a (sampler, model,
                              retrain cadence); reuse across streams/seeds.
  * :func:`run_loop`       -- convenience one-shot wrapper.
  * :func:`make_run_farm` / :func:`run_farm` -- ``vmap`` the whole loop over
    Monte-Carlo trials (the paper's Fig. 12/13 robustness protocol: many
    sampler realizations over one stream, metric quantiles over trials).
  * :func:`materialize_stream` -- stack a host-side generator from
    :mod:`repro.data.streams` into the fixed-shape [T, bcap, ...] arrays the
    scan consumes.

The ``make_*`` builders are memoized on (sampler, model, retrain cadence), so
the one-shot wrappers and the Fig. 12/13 drivers never recompile an identical
program (Samplers/ModelAdapters hash by identity).

Distributed schemes (the paper's Sec. 5 D-R-TBS / D-T-TBS) run the SAME loop
at cluster scale (DESIGN.md Sec. 10):
  * :func:`make_sharded_run_loop` -- the identical tick structure, with the
    whole scan running under ``shard_map`` over the ``data`` mesh axis:
    co-partitioned batches, replicated params, one psum per tick, and a
    global-:class:`~repro.core.api.SampleView` assembly (all_gather of shard
    prefixes + the reserved fractional-item slot) feeding ``model.fit``.
  * :func:`make_sharded_manage_step` -- the unfused per-tick shard_map driver
    (one dispatch per tick, state round-tripped through its replicated
    :func:`~repro.core.distributed.gather_tree` snapshot); bit-identical to
    the fused loop, and the benchmark's comparison point.
  * :func:`make_sharded_run_farm` -- Monte-Carlo trials ``vmap``-ed INSIDE the
    shard_map over replicated trial keys, sharing one co-partitioned stream.
  * :func:`make_sharded_resume_loop` -- checkpoint/resume for the fused
    sharded run: consume a ``gather_tree`` snapshot + a global start tick and
    continue bit-exactly (the key discipline below makes this trivial).
  * :func:`shard_stream` -- re-pack a :func:`materialize_stream` output into
    co-partitioned per-shard segments ([T, S*bcap_s, ...] / [T, S]).

Closed-loop adaptive decay (DESIGN.md Sec. 12): every loop builder accepts
``controller=`` (a :class:`repro.decay.AdaptiveDecay`); the controller's rate
drives ``sampler.step_decayed`` each tick, the prequential metric feeds the
controller back, and the rate adjustment is gated on retrain ticks -- all
inside the same compiled scan, superbatch-compatible, with the applied
factor logged in the trace under ``"decay"``.

Key discipline (bit-exact replays, and what tests assert): tick t uses
``fold_in(key, t)`` split into (step, extract, fit) subkeys, so a fused run,
an unfused per-tick driver, and a checkpoint-resumed run all see identical
randomness. Sharded runs pass the SAME replicated key to every shard (the
samplers fold in the shard index where shard-local draws are needed), so the
discipline carries over unchanged. On non-retrain ticks only the cheap
``sampler.size`` path runs -- ``extract`` (a prefix permutation + RNG draw for
R-TBS) happens under the retrain ``lax.cond``, with identical traces because
size and extract consume the same fold_in subkey.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed
from repro.core.api import Sampler
from repro.manage.models import ModelAdapter
from repro.obs import probe as _obs_probe
from repro.obs.profile import scope as _scope


def tick_keys(key: jax.Array, t) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The loop's per-tick (step, extract, fit) keys -- public so unfused
    drivers and tests can reproduce the fused loop exactly."""
    return tuple(jax.random.split(jax.random.fold_in(key, t), 3))


def item_proto(batches: Any) -> Any:
    """ONE-item prototype from stacked stream arrays (leaves [T, bcap, ...])."""
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[2:], a.dtype), batches
    )


def _check_local(sampler: Sampler) -> None:
    if sampler.distributed:
        raise ValueError(
            f"sampler {sampler.scheme!r} is a per-shard scheme: its step/extract "
            f"must run under jax.shard_map over the {distributed.AXIS!r} axis "
            "and cannot drive the single-host manage loop directly -- use "
            "make_sharded_run_loop(sampler, model, mesh)"
        )


def _check_sharded(sampler: Sampler) -> None:
    if not sampler.distributed or sampler.extract_global is None:
        raise ValueError(
            f"sampler {sampler.scheme!r} is a local scheme: the sharded manage "
            "loop needs per-shard step/extract_global closures (drtbs/dttbs) "
            "-- use make_run_loop for local schemes"
        )


def _check_controllable(sampler: Sampler) -> None:
    if sampler.step_decayed is None:
        raise ValueError(
            f"sampler {sampler.scheme!r} has no decay to control (no "
            "step_decayed closure) -- the adaptive controller drives the "
            "time-biased schemes (rtbs/ttbs/btbs/drtbs/dttbs), not the "
            "decay-free baselines"
        )


def _effective_superbatch(superbatch: int | None, retrain_every: int) -> int:
    """Resolve the superbatch chunk size G: the largest divisor of
    ``retrain_every`` not exceeding the requested size. G must divide
    ``retrain_every`` so that within a G-tick chunk only the LAST tick can be
    a retrain tick -- the first G-1 ticks then compile with no fit branch at
    all (DESIGN.md Sec. 11).

    Default (None): 8 on TPU, 1 elsewhere. On CPU the XLA while-loop already
    optimizes the small per-tick body best and a per-tick ``lax.cond`` is
    free, so unrolling REGRESSES throughput ~2x (measured, recorded in
    BENCH_manage_loop.json's ``manage_loop_fused_sb8`` row); on TPU the
    chunked body amortizes per-iteration dispatch and carry double-buffering.
    """
    if superbatch is None:
        superbatch = 8 if jax.default_backend() == "tpu" else 1
    want = max(int(superbatch), 1)
    g = min(want, retrain_every)
    while retrain_every % g:
        g -= 1
    return g


def _make_fast_tick(sampler: Sampler, model: ModelAdapter) -> Callable:
    """The non-retrain fast path of a superbatched chunk: evaluate + step +
    the payload-free size metric, with NO fit conditional in the trace.
    Bit-identical to :func:`make_manage_step`'s tick on ticks where
    ``(t+1) % retrain_every != 0`` (same tick_keys, same op order)."""

    def fast(key, t, state, params, batch_items, bcount):
        k_step, k_extract, _ = tick_keys(key, t)
        with _scope("manage.eval"):
            metric = model.evaluate(params, batch_items, bcount)
        with _scope("manage.sampler_step"):
            state = sampler.step(k_step, state, batch_items, bcount)
        with _scope("manage.size"):
            size = sampler.size(k_extract, state)
        return state, {"metric": metric, "size": size}

    return fast


def _make_controlled_ticks(sampler: Sampler, model: ModelAdapter,
                           controller, retrain_every: int,
                           metric_fn: Callable | None = None,
                           extract_attr: str = "extract",
                           size_attr: str = "size") -> tuple[Callable, Callable]:
    """Carry-form (full, fast) ticks with a closed-loop decay controller
    (:mod:`repro.decay.adaptive`) in the loop: carry is ``(state, params,
    cstate)``.  Per tick the controller's current rate feeds
    ``sampler.step_decayed`` and the prequential metric feeds
    ``controller.observe``; the lambda *adjustment* is gated on retrain ticks
    (``adjust = do_fit``), so the controller only reacts at the cadence where
    the loss can actually respond to a rate change.  The fast tick passes a
    static ``adjust=False`` -- same arithmetic as the full tick's traced
    False, so superbatched runs stay bit-identical to G=1.  The per-tick
    factor ``d_t`` is logged in the trace under ``"decay"``.

    ``metric_fn``/``extract_attr``/``size_attr`` let the sharded loop reuse
    this skeleton with its psum'd metric and global extract closures.
    """
    metric_of = metric_fn or (
        lambda params, b, c: model.evaluate(params, b, c)
    )
    extract = getattr(sampler, extract_attr)
    size = getattr(sampler, size_attr)

    def full(key, t, carry, batch_items, bcount):
        state, params, cstate = carry
        k_step, k_extract, k_fit = tick_keys(key, t)
        with _scope("manage.eval"):
            metric = metric_of(params, batch_items, bcount)
        with _scope("manage.sampler_step"):
            d = controller.rate(cstate)
            state = sampler.step_decayed(k_step, state, batch_items, bcount,
                                         d)
        do_fit = (t + 1) % retrain_every == 0
        cstate = controller.observe(cstate, metric, do_fit)
        with _scope("manage.retrain"):
            params = jax.lax.cond(
                do_fit,
                lambda: model.fit(k_fit, params, extract(k_extract, state)),
                lambda: params,
            )
        with _scope("manage.size"):
            m = {"metric": metric, "size": size(k_extract, state), "decay": d}
        return (state, params, cstate), m

    def fast(key, t, carry, batch_items, bcount):
        state, params, cstate = carry
        k_step, k_extract, _ = tick_keys(key, t)
        with _scope("manage.eval"):
            metric = metric_of(params, batch_items, bcount)
        with _scope("manage.sampler_step"):
            d = controller.rate(cstate)
            state = sampler.step_decayed(k_step, state, batch_items, bcount,
                                         d)
        cstate = controller.observe(cstate, metric, False)
        with _scope("manage.size"):
            m = {"metric": metric, "size": size(k_extract, state), "decay": d}
        return (state, params, cstate), m

    return full, fast


def _superbatched_scan(tick: Callable, fast: Callable, G: int) -> Callable:
    """The chunked-scan skeleton shared by the local and sharded loops:
    ``scan(key, carry0, batches, bcounts, t0=0) -> (carry, trace)``.

    ``tick``/``fast`` operate on an opaque loop carry -- ``(key, t, carry,
    batch, bcount) -> (carry, metrics)`` -- so the same skeleton serves the
    plain (state, params) loops and the controller-augmented ones. ``t0``
    offsets the global tick index (checkpoint/resume: the resumed segment
    replays ``fold_in(key, t0 + i)`` exactly as the unbroken run would);
    callers must keep ``t0 % G == 0`` so chunk boundaries stay aligned with
    the retrain cadence.

    Scans T//G chunks of G ticks; within a chunk the first G-1 ticks run the
    cond-free ``fast`` path (G divides the retrain cadence, so only the last
    tick of a chunk can retrain -- :func:`_effective_superbatch`) and the
    last runs the full ``tick``. Tail ticks (T % G) run ``tick`` unrolled
    after the scan. Bit-identical to the G=1 per-tick scan for any G."""

    def scan(key, carry0, batches, bcounts, t0=0):
        T = bcounts.shape[0]
        nchunks = T // G
        Tm = nchunks * G
        t0 = jnp.asarray(t0, jnp.int32)

        def at(tree, idx):
            return jax.tree_util.tree_map(lambda a: a[idx], tree)

        def chunk(a):
            return a[:Tm].reshape((nchunks, G) + a.shape[1:])

        def chunk_body(carry, inp):
            ct, cb, cc = inp
            ms = []
            for g in range(G - 1):       # unrolled, no retrain conditional
                carry, m = fast(key, ct[g], carry, at(cb, g), cc[g])
                ms.append(m)
            carry, m = tick(key, ct[G - 1], carry, at(cb, G - 1), cc[G - 1])
            ms.append(m)
            metrics = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ms)
            return carry, metrics

        carry, trace = jax.lax.scan(
            chunk_body, carry0,
            (chunk(t0 + jnp.arange(T, dtype=jnp.int32)),
             jax.tree_util.tree_map(chunk, batches), chunk(bcounts)),
        )
        trace = jax.tree_util.tree_map(
            lambda a: a.reshape((Tm,) + a.shape[2:]), trace
        )
        tails = []
        for t in range(Tm, T):
            carry, m = tick(key, t0 + jnp.int32(t), carry,
                            at(batches, t), bcounts[t])
            tails.append(m)
        if tails:
            tailm = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *tails)
            trace = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b]), trace, tailm
            )
        return carry, trace

    return scan


def _wrap_stats(fn: Callable, stats_fn: Callable) -> Callable:
    """Wrap a loop tick so its metrics become ``(m, row)``: the trace entry
    plus one fixed-shape telemetry stats row. A tick's metrics dict may
    carry a reserved ``"_obs"`` entry (telemetry-only columns, e.g. bank
    routing stats): it is diverted to ``stats_fn`` and stripped from the
    trace."""

    def wrapped(key, t, carry, batch, bcount):
        carry, m = fn(key, t, carry, batch, bcount)
        obs = {}
        if isinstance(m, dict) and "_obs" in m:
            m = dict(m)
            obs = m.pop("_obs")
        with _scope("obs.stats"):
            row = stats_fn(t, batch, bcount, carry, m, obs)
        return carry, (m, row)

    return wrapped


def _telemetry_fetch_scan(tick: Callable, fast: Callable, G: int, telem,
                          stats_fn: Callable) -> Callable:
    """The ``"fetch"`` drain transport (DESIGN.md Sec. 14): the plain
    :func:`_superbatched_scan` with the per-tick stats rows riding the scan
    ys next to the trace -- NO host callback anywhere in the compiled
    module. ``scan(...) -> (carry, trace, rows)`` where ``rows`` is the
    [T]-stacked column dict; the run wrapper (:func:`_wrap_run_header`)
    fetches it after the jitted call and feeds ``telem.every``-tick blocks
    to :meth:`repro.obs.Telemetry._drain_cb`, preserving the callback
    transport's tick-record stream (same records, same order; only the
    trailing partial block may coalesce where the callback transport
    drains rem-chunks and unrolled tails separately). Fast ticks do zero
    host transfers; the one fetch at the end
    is the explicitly-allowed drain (the wrapper opts it out of
    ``jax.transfer_guard_device_to_host``)."""
    tick_w, fast_w = _wrap_stats(tick, stats_fn), _wrap_stats(fast, stats_fn)
    inner = _superbatched_scan(tick_w, fast_w, G)

    def scan(key, carry0, batches, bcounts, t0=0):
        carry, (trace, rows) = inner(key, carry0, batches, bcounts, t0)
        return carry, trace, rows

    return scan


def _telemetry_scan(tick: Callable, fast: Callable, G: int, telem,
                    stats_fn: Callable,
                    shard_axis: str | None = None) -> Callable:
    """The :func:`_superbatched_scan` skeleton with in-scan telemetry
    (DESIGN.md Sec. 14): every tick additionally computes one fixed-shape
    stats row (``stats_fn(t, batch, bcount, carry, m, obs) -> {col:
    scalar}``), rows accumulate on-device in the scan stack, and blocks of
    ``telem.every`` ticks (rounded down to whole G-chunks, floor one chunk)
    drain to :meth:`repro.obs.Telemetry._drain_cb` at chunk-group
    boundaries -- the fast ticks inside a chunk never touch the host, and
    the drain does not trip ``jax.transfer_guard_device_to_host`` (asserted
    in tests/test_obs.py).

    The drain transport is ``jax.pure_callback`` with a token chained
    through every drain, NOT the effectful callbacks: any effect-carrying
    host callback (``io_callback`` ordered or not, ``debug.callback``) in
    the compiled module serializes XLA:CPU thunk execution and was measured
    to cost ~40% on the cap-4096 fused loop REGARDLESS of drain frequency
    -- even a single top-level drain per run; ``pure_callback`` keeps the
    concurrent executor and measures in the noise (benchmarks/
    obs_overhead.py). Each drain consumes the previous drain's token and
    returns the next, so the data dependency forces drains to run in stream
    order, and the final token is threaded out of the jitted program by
    every caller so the chain is never dead-code-eliminated. The callback
    mutates host state behind a nominally pure op -- sanctioned here because
    nothing in the computation reads it back: worst case under exotic
    re-execution is a duplicated telemetry block, never a wrong sample.

    Structure: the T//G chunks are grouped into periods of P = every // G
    chunks; an outer scan over whole periods runs an inner scan of P chunks
    then drains the period's P*G rows; leftover chunks (< P) run in one more
    scan with their own drain; tail ticks (T % G) run unrolled and drain
    last. The tick composition -- G-1 fast + 1 full per chunk, tails full --
    is IDENTICAL to :func:`_superbatched_scan`, so the returned ``(carry,
    trace)`` is bit-identical to the telemetry-off loop for any (G, every).
    Returns ``scan(key, carry0, batches, bcounts, t0=0) -> (carry, trace,
    token)``.

    A tick's metrics dict may carry a reserved ``"_obs"`` entry (telemetry-
    only columns, e.g. bank routing stats): it is diverted to ``stats_fn``
    and stripped from the trace. Under ``shard_map`` pass ``shard_axis``:
    every shard drains (the callback fires per shard) but the host keeps
    only shard 0's stream -- the stats columns are replicated or shard-0
    quantities by construction, and so is the returned token.
    """
    P = max(int(telem.every) // G, 1)

    def _host_drain(me, rows, tok):
        telem._drain_cb(me, rows)
        return np.int32(int(tok) + 1)

    tick_w, fast_w = _wrap_stats(tick, stats_fn), _wrap_stats(fast, stats_fn)

    def scan(key, carry0, batches, bcounts, t0=0):
        T = bcounts.shape[0]
        nchunks = T // G
        Tm = nchunks * G
        t0 = jnp.asarray(t0, jnp.int32)
        nper = nchunks // P
        Tp = nper * P * G
        me = (jax.lax.axis_index(shard_axis) if shard_axis is not None
              else jnp.int32(0))
        ticks = t0 + jnp.arange(T, dtype=jnp.int32)

        def at(tree, idx):
            return jax.tree_util.tree_map(lambda a: a[idx], tree)

        def part(tree, lo, hi, prefix):
            return jax.tree_util.tree_map(
                lambda a: a[lo:hi].reshape(prefix + a.shape[1:]), tree
            )

        def chunk_body(carry, inp):
            ct, cb, cc = inp
            outs = []
            for g in range(G - 1):
                carry, o = fast_w(key, ct[g], carry, at(cb, g), cc[g])
                outs.append(o)
            carry, o = tick_w(key, ct[G - 1], carry, at(cb, G - 1), cc[G - 1])
            outs.append(o)
            return carry, jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs
            )

        def drain(rows_flat, tok):
            return jax.pure_callback(
                _host_drain, jax.ShapeDtypeStruct((), jnp.int32),
                me, rows_flat, tok,
            )

        def flat2(tree, n):
            return jax.tree_util.tree_map(
                lambda a: a.reshape((n,) + a.shape[2:]), tree
            )

        traces = []
        carry = carry0
        tok = jnp.int32(0)

        if nper:
            inp = (part(ticks, 0, Tp, (nper, P, G)),
                   part(batches, 0, Tp, (nper, P, G)),
                   part(bcounts, 0, Tp, (nper, P, G)))

            def period_body(ct, pin):
                carry, tok = ct
                carry, (m, rows) = jax.lax.scan(chunk_body, carry, pin)
                tok = drain(flat2(rows, P * G), tok)
                return (carry, tok), m

            (carry, tok), m = jax.lax.scan(period_body, (carry, tok), inp)
            traces.append(jax.tree_util.tree_map(
                lambda a: a.reshape((Tp,) + a.shape[3:]), m
            ))

        rem = nchunks - nper * P
        if rem:
            inp = (part(ticks, Tp, Tm, (rem, G)),
                   part(batches, Tp, Tm, (rem, G)),
                   part(bcounts, Tp, Tm, (rem, G)))
            carry, (m, rows) = jax.lax.scan(chunk_body, carry, inp)
            tok = drain(flat2(rows, rem * G), tok)
            traces.append(flat2(m, rem * G))

        tails_m, tails_r = [], []
        for t in range(Tm, T):
            carry, (m, row) = tick_w(key, t0 + jnp.int32(t), carry,
                                     at(batches, t), bcounts[t])
            tails_m.append(m)
            tails_r.append(row)
        if tails_r:
            tok = drain(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                               *tails_r), tok)
            traces.append(jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *tails_m
            ))

        if not traces:  # T == 0: an empty scan still shapes the trace
            carry, (m, _) = jax.lax.scan(
                chunk_body, carry,
                (part(ticks, 0, 0, (0, G)), part(batches, 0, 0, (0, G)),
                 part(bcounts, 0, 0, (0, G))),
            )
            return carry, flat2(m, 0), tok

        trace = traces[0] if len(traces) == 1 else jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs), *traces
        )
        return carry, trace, tok

    return scan


def _make_loop_stats(sampler: Sampler, controller,
                     retrain_every: int) -> Callable:
    """The single-sampler loops' telemetry row: per-tick sample size, the
    stored mass C / decayed weight W gauges (:func:`repro.obs.probe.
    make_state_stats`), the retrain flag, the applied decay factor (from the
    controller trace entry, else the schedule's static rate), and the
    controller's lambda/hold/pulse gauges when one is in the carry."""
    state_stats = _obs_probe.make_state_stats(sampler)
    d0 = _obs_probe.static_decay(sampler)
    cstats = getattr(controller, "stats", None)

    def stats_fn(t, batch, bcount, carry, m, obs):
        del batch, obs
        t = jnp.asarray(t, jnp.int32)
        row = {
            "t": t,
            "bcount": jnp.asarray(bcount, jnp.int32),
            "metric": jnp.asarray(m["metric"], jnp.float32),
            "size": jnp.asarray(m["size"], jnp.int32),
            "retrain": (t + 1) % retrain_every == 0,
        }
        row.update(state_stats(carry[0]))
        if "decay" in m:
            row["decay"] = jnp.asarray(m["decay"], jnp.float32)
        elif d0 is not None:
            row["decay"] = jnp.float32(d0)
        if cstats is not None:
            row.update(cstats(carry[2]))
        return row

    return stats_fn


def _wrap_run_header(jitted: Callable, telemetry, *, scheme: str, G: int,
                     init: Callable, proto_of: Callable) -> Callable:
    """Wrap a compiled loop so each invocation opens a telemetry run: one
    ``kind="run"`` header record (static facts incl. the reservoir-state
    bytes gauge via ``jax.eval_shape``, computed once per loop -- nothing
    materializes), then the jitted call. The jitted program returns the
    user outputs plus a transport-dependent aux: the drain-chain token
    (:func:`_telemetry_scan` -- blocking on it guarantees every drained
    record has reached the sinks) or the stacked rows dict
    (:func:`_telemetry_fetch_scan` -- drained here, in ``telemetry.every``
    blocks, through the same ``_drain_cb``). Either way the aux is stripped
    from what the caller sees."""
    cache: dict = {}

    def run(key, batches, bcounts):
        if "state_bytes" not in cache:
            try:
                cache["state_bytes"] = _obs_probe.state_nbytes(
                    init, proto_of(batches))
            except Exception:
                cache["state_bytes"] = None  # e.g. init needs a collective
        telemetry.open_run({
            "scheme": scheme,
            "ticks": int(bcounts.shape[0]),
            "superbatch": G,
            "every": telemetry.every,
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "state_bytes": cache["state_bytes"],
        })
        *out, aux = jitted(key, batches, bcounts)
        if isinstance(aux, dict):  # fetch transport: drain the stacked rows
            with jax.transfer_guard_device_to_host("allow"):
                cols = {k: np.asarray(v) for k, v in aux.items()}
            n = min((c.shape[0] for c in cols.values()), default=0)
            every = max(telemetry.every // G, 1) * G
            for s in range(0, n, every):
                telemetry._drain_cb(
                    0, {k: c[s:s + every] for k, c in cols.items()})
        else:
            jax.block_until_ready(aux)  # the chain: all drains have landed
        telemetry.flush()
        return tuple(out)

    return run


def _pair_carry(tick: Callable, fast: Callable) -> tuple[Callable, Callable]:
    """Adapt the public (state, params)-signature tick builders to the
    opaque-carry contract of :func:`_superbatched_scan`."""

    def tick_c(key, t, carry, batch_items, bcount):
        state, params, m = tick(key, t, carry[0], carry[1], batch_items, bcount)
        return (state, params), m

    def fast_c(key, t, carry, batch_items, bcount):
        state, m = fast(key, t, carry[0], carry[1], batch_items, bcount)
        return (state, carry[1]), m

    return tick_c, fast_c


def _make_local_tick(sampler: Sampler, model: ModelAdapter,
                     retrain_every: int) -> Callable:
    """The raw (unjitted) local tick body shared by :func:`make_run_loop`'s
    scan and the jitted per-tick driver :func:`make_manage_step`."""

    def step(key, t, state, params, batch_items, bcount):
        k_step, k_extract, k_fit = tick_keys(key, t)
        with _scope("manage.eval"):
            metric = model.evaluate(params, batch_items, bcount)
        with _scope("manage.sampler_step"):
            state = sampler.step(k_step, state, batch_items, bcount)

        # extract (full prefix permutation + realization draw) only runs on
        # retrain ticks; the per-tick size metric takes the payload-free path.
        # Both consume k_extract, so sizes/views agree and traces are
        # unchanged vs. extracting every tick.
        do_fit = (t + 1) % retrain_every == 0
        with _scope("manage.retrain"):
            params = jax.lax.cond(
                do_fit,
                lambda: model.fit(k_fit, params,
                                  sampler.extract(k_extract, state)),
                lambda: params,
            )
        with _scope("manage.size"):
            metrics = {"metric": metric,
                       "size": sampler.size(k_extract, state)}
        return state, params, metrics

    return step


def make_manage_step(sampler: Sampler, model: ModelAdapter, *,
                     retrain_every: int = 1) -> Callable:
    """One tick of the loop as its own jitted dispatch: ``(key, t, state,
    params, batch, bcount) -> (state, params, metrics)``. Composable: the
    same tick body is what :func:`make_run_loop` scans, so driving it
    tick-by-tick (checkpointing, serving, human-in-the-loop) stays
    bit-identical to the fused run.

    The sampler ``state`` (arg 2) is DONATED on backends that support
    donation (not CPU), matching the sharded per-tick driver: the driver
    round-trips the reservoir every dispatch, so donation lets XLA reuse its
    buffers in place instead of double-buffering -- do not reuse a state
    after passing it in. The reservoir stays device-resident across ticks:
    nothing in the tick forces a host copy (asserted under a
    device-to-host transfer guard in tests/test_api.py)."""
    _check_local(sampler)

    def build():
        donate = () if jax.default_backend() == "cpu" else (2,)
        return jax.jit(_make_local_tick(sampler, model, retrain_every),
                       donate_argnums=donate)

    return _memoized(
        "manage_step",
        (sampler, model, retrain_every, jax.default_backend()),
        build,
    )


_BUILD_CACHE: OrderedDict[tuple, Callable] = OrderedDict()
_BUILD_CACHE_MAX = 64


def _memoized(kind: str, key: tuple, build: Callable[[], Callable]) -> Callable:
    """Memoize compiled-loop builders on (kind, sampler, model, ...): repeat
    calls (the one-shot wrappers, the Fig. 12/13 drivers re-dispatching per
    scheme/seed) return the SAME jitted callable, so jax's jit cache is hit
    instead of re-tracing an identical program.

    LRU-bounded: Samplers/ModelAdapters hash by identity, so a sweep that
    builds a fresh sampler per configuration gets no hits and would otherwise
    pin every compiled program for process lifetime."""
    full = (kind, *key)
    hit = _BUILD_CACHE.get(full)
    if hit is None:
        hit = _BUILD_CACHE[full] = build()
        if len(_BUILD_CACHE) > _BUILD_CACHE_MAX:
            _BUILD_CACHE.popitem(last=False)
    else:
        _BUILD_CACHE.move_to_end(full)
    return hit


def make_run_loop(sampler: Sampler, model: ModelAdapter, *,
                  retrain_every: int = 1,
                  superbatch: int | None = None,
                  controller=None, telemetry=None) -> Callable:
    """Compile the full-stream loop once.

    Returns ``run(key, batches, bcounts) -> (state, params, trace)`` where
    ``batches`` leaves are [T, bcap, ...], ``bcounts`` is [T] int32, and
    ``trace`` holds per-tick {"metric" f32[T], "size" i32[T]}. The whole
    stream is consumed by ONE jitted ``lax.scan`` -- no per-tick dispatch.

    ``superbatch`` coalesces G consecutive ticks into one chunked scan body
    (G = largest divisor of ``retrain_every`` <= superbatch; default: 8 on
    TPU, 1 elsewhere -- see :func:`_effective_superbatch`): the first G-1
    ticks of each chunk are unrolled WITHOUT the retrain conditional, so the
    non-retrain fast path pays scan bookkeeping (carry double-buffering,
    per-iteration dispatch) once per chunk instead of once per tick. Results
    are bit-identical for any G (asserted in tests).

    ``controller`` (a :class:`repro.decay.AdaptiveDecay`) closes the loop
    between the prequential metric and the sampler's decay rate INSIDE the
    same compiled scan (DESIGN.md Sec. 12): each tick the controller's
    current rate drives ``sampler.step_decayed`` and the metric updates the
    controller; the rate adjustment itself is gated on retrain ticks. The
    trace gains a per-tick ``"decay"`` entry (the applied factor d_t). The
    sampler must be decay-capable (rtbs/ttbs/btbs); without a controller the
    program is exactly the historical one.

    ``telemetry`` (a :class:`repro.obs.Telemetry`) threads in-scan
    observability (DESIGN.md Sec. 14): every tick computes a stats row
    on-device and ``telemetry.every``-tick blocks drain to the host sinks
    over the handle's transport (fetched as jit outputs after the run, or
    live at chunk-group boundaries through a token-chained
    ``pure_callback``); each invocation additionally emits a ``kind="run"``
    header. The returned ``(state,
    params, trace)`` stays bit-identical to the telemetry-off program
    (asserted in tests/test_obs.py); ``telemetry=None`` compiles exactly
    the historical loop.

    Memoized on ``(sampler, model, retrain_every, superbatch, controller,
    telemetry)``: repeat calls return the same compiled callable.
    """
    return _memoized(
        "run_loop",
        (sampler, model, retrain_every, superbatch, controller, telemetry),
        lambda: _build_run_loop(sampler, model, retrain_every, superbatch,
                                controller, telemetry),
    )


def _build_run_loop(sampler: Sampler, model: ModelAdapter,
                    retrain_every: int, superbatch: int | None,
                    controller=None, telemetry=None) -> Callable:
    _check_local(sampler)
    if controller is None:
        tick, fast = _pair_carry(
            _make_local_tick(sampler, model, retrain_every),
            _make_fast_tick(sampler, model),
        )
    else:
        _check_controllable(sampler)
        tick, fast = _make_controlled_ticks(sampler, model, controller,
                                            retrain_every)
    G = _effective_superbatch(superbatch, retrain_every)
    if telemetry is None:
        scan = _superbatched_scan(tick, fast, G)
    else:
        stats = _make_loop_stats(sampler, controller, retrain_every)
        if telemetry.resolve_transport() == "fetch":
            scan = _telemetry_fetch_scan(tick, fast, G, telemetry, stats)
        else:
            scan = _telemetry_scan(tick, fast, G, telemetry, stats)

    @jax.jit
    def run(key, batches, bcounts):
        carry0 = (sampler.init(item_proto(batches)), model.init())
        if controller is not None:
            carry0 = carry0 + (controller.init(),)
        if telemetry is None:
            carry, trace = scan(key, carry0, batches, bcounts)
            return carry[0], carry[1], trace
        carry, trace, aux = scan(key, carry0, batches, bcounts)
        return carry[0], carry[1], trace, aux

    if telemetry is None:
        return run
    return _wrap_run_header(run, telemetry, scheme=sampler.scheme, G=G,
                            init=sampler.init, proto_of=item_proto)


def run_loop(key: jax.Array, sampler: Sampler, model: ModelAdapter,
             batches: Any, bcounts: jax.Array, *, retrain_every: int = 1,
             superbatch: int | None = None, controller=None):
    """One-shot convenience wrapper over :func:`make_run_loop`."""
    return make_run_loop(sampler, model, retrain_every=retrain_every,
                         superbatch=superbatch,
                         controller=controller)(key, batches, bcounts)


def make_run_farm(sampler: Sampler, model: ModelAdapter, *,
                  retrain_every: int = 1,
                  superbatch: int | None = None,
                  controller=None) -> Callable:
    """Monte-Carlo farm: ``farm(key, trials, batches, bcounts) -> trace``.

    ``vmap`` of the fused loop over ``trials`` independent sampler/model
    randomness streams sharing one data stream; trace leaves gain a leading
    [trials] axis. This is the Fig. 12/13 robustness protocol (mean + expected
    shortfall over realizations) as one compiled program. Memoized like
    :func:`make_run_loop`; ``controller`` is threaded through unchanged (each
    trial carries its own controller state).
    """

    def build():
        run = make_run_loop(sampler, model, retrain_every=retrain_every,
                            superbatch=superbatch, controller=controller)

        def farm(key, trials: int, batches, bcounts):
            keys = jax.random.split(key, trials)
            _, _, trace = jax.vmap(lambda k: run(k, batches, bcounts))(keys)
            return trace

        return farm

    return _memoized(
        "run_farm", (sampler, model, retrain_every, superbatch, controller),
        build
    )


def run_farm(key: jax.Array, trials: int, sampler: Sampler,
             model: ModelAdapter, batches: Any, bcounts: jax.Array, *,
             retrain_every: int = 1, superbatch: int | None = None,
             controller=None):
    """One-shot convenience wrapper over :func:`make_run_farm`."""
    return make_run_farm(sampler, model, retrain_every=retrain_every,
                         superbatch=superbatch,
                         controller=controller)(key, trials, batches, bcounts)


# ---------------------------------------------------------------------------
# the sharded loop: the same tick, run per-shard under shard_map (paper Sec. 5)
# ---------------------------------------------------------------------------
def _make_sharded_tick(sampler: Sampler, model: ModelAdapter,
                       retrain_every: int) -> Callable:
    """The per-shard tick body shared by the fused loop and the per-tick
    driver. Mirrors :func:`make_manage_step` exactly, with the three global
    touch points of the paper's Fig. 6(b) protocol:

      * the prequential metric is the |B_t|-weighted psum of per-shard metrics
        (NaN only when the GLOBAL tick is empty). The weighting assumes
        ``model.evaluate`` honors ``bcount`` (all closed-form adapters do);
        an adapter that averages over every row -- the SGD adapter's default
        scalar LM loss -- additionally needs padding-free shard segments, see
        :func:`repro.manage.models.make_sgd_adapter`,
      * ``model.fit`` consumes ``sampler.extract_global`` -- the replicated
        whole-mesh :class:`~repro.core.api.SampleView` -- so params stay
        replicated by construction,
      * the per-tick size metric takes the payload-free ``size_global`` path
        (extract_global's all_gather only runs on retrain ticks).
    """
    metric_of = _psum_metric(model)

    def tick(key, t, state, params, batch_items, bcount):
        k_step, k_extract, k_fit = tick_keys(key, t)
        with _scope("manage.eval"):
            metric = metric_of(params, batch_items, bcount)

        with _scope("manage.sampler_step"):
            state = sampler.step(k_step, state, batch_items, bcount)

        do_fit = (t + 1) % retrain_every == 0
        with _scope("manage.retrain"):
            params = jax.lax.cond(
                do_fit,
                lambda: model.fit(
                    k_fit, params, sampler.extract_global(k_extract, state)
                ),
                lambda: params,
            )
        with _scope("manage.size"):
            size = sampler.size_global(k_extract, state)
        return state, params, {"metric": metric, "size": size}

    return tick


def _psum_metric(model: ModelAdapter) -> Callable:
    """The sharded loops' prequential metric: |B_t|-weighted psum of
    per-shard metrics over the data axis (NaN only when the GLOBAL tick is
    empty)."""
    axis = distributed.AXIS

    def metric_of(params, batch_items, bcount):
        m_s = model.evaluate(params, batch_items, bcount)
        w_s = jnp.asarray(bcount, jnp.float32)
        num = jax.lax.psum(jnp.where(bcount > 0, m_s, 0.0) * w_s, axis)
        den = jax.lax.psum(w_s, axis)
        return jnp.where(den > 0, num / jnp.maximum(den, 1.0),
                         jnp.float32(jnp.nan))

    return metric_of


def _make_sharded_fast_tick(sampler: Sampler, model: ModelAdapter) -> Callable:
    """Sharded analogue of :func:`_make_fast_tick`: the per-shard tick without
    the retrain conditional (no extract_global all_gather in the trace) --
    the superbatched chunk's non-retrain fast path."""
    metric_of = _psum_metric(model)

    def fast(key, t, state, params, batch_items, bcount):
        k_step, k_extract, _ = tick_keys(key, t)
        with _scope("manage.eval"):
            metric = metric_of(params, batch_items, bcount)
        with _scope("manage.sampler_step"):
            state = sampler.step(k_step, state, batch_items, bcount)
        with _scope("manage.size"):
            size = sampler.size_global(k_extract, state)
        return state, {"metric": metric, "size": size}

    return fast


def _sharded_in_specs(axis):
    from jax.sharding import PartitionSpec as P

    # (key replicated, batch leaves [T, S*bcap_s, ...] split on dim 1,
    #  bcounts [T, S] split on dim 1); P(None, axis) broadcasts over the
    # batches pytree as a spec prefix.
    return (P(), P(None, axis), P(None, axis))


def _make_controlled_sharded_ticks(sampler: Sampler, model: ModelAdapter,
                                   controller,
                                   retrain_every: int) -> tuple[Callable, Callable]:
    """Sharded controller ticks: the :func:`_make_controlled_ticks` skeleton
    with the psum'd metric and the global extract/size closures. The metric
    fed to ``controller.observe`` is the replicated global one and the
    controller update is deterministic, so the controller state stays
    replicated across shards by construction."""
    return _make_controlled_ticks(
        sampler, model, controller, retrain_every,
        metric_fn=_psum_metric(model),
        extract_attr="extract_global",
        size_attr="size_global",
    )


def make_sharded_run_loop(sampler: Sampler, model: ModelAdapter, mesh, *,
                          retrain_every: int = 1,
                          superbatch: int | None = None,
                          controller=None, telemetry=None) -> Callable:
    """Compile the paper's model-management loop for a sharded sampler.

    Returns ``run(key, batches, bcounts) -> (state, params, trace)``:

      * ``batches``: pytree, leaves [T, S*bcap_s, ...] -- tick t's arrivals,
        co-partitioned so shard s owns slots [s*bcap_s, (s+1)*bcap_s)
        (:func:`shard_stream` builds this layout from a materialized stream);
      * ``bcounts``: [T, S] int32 valid-prefix counts per shard (empty shards
        are fine -- the schemes psum the global |B_t|);
      * ``state``: the final sampler state as the replicated
        :func:`~repro.core.distributed.gather_tree` snapshot (every leaf
        gains a leading [S] axis);
      * ``params``/``trace``: replicated, identical shapes and key discipline
        as :func:`make_run_loop`.

    The whole stream runs as ONE jitted ``lax.scan`` executing inside
    ``shard_map`` over the ``data`` axis, so reservoir shards stay resident on
    their devices for the entire stream: per tick there is exactly one scalar
    psum (|B_t|) plus the sampler's own tiny count collectives, and payloads
    cross shards only inside ``extract_global`` on retrain ticks.
    ``superbatch`` chunks the scan exactly as in :func:`make_run_loop` (the
    non-retrain fast ticks additionally drop the retrain-gated all_gather
    from their trace). ``controller`` threads the closed-loop decay
    controller exactly as in :func:`make_run_loop` -- it observes the psum'd
    global metric, so its state stays replicated. ``telemetry`` threads
    in-scan observability exactly as in :func:`make_run_loop`; every shard
    reaches the drain callback with its own axis index and the host
    keeps only shard 0's stream (the drained columns are replicated or
    shard-0 gauges). Memoized on ``(sampler, model, mesh, retrain_every,
    superbatch, controller, telemetry)``.
    """
    _check_sharded(sampler)
    if controller is not None:
        _check_controllable(sampler)

    def build():
        jitted = jax.jit(distributed.shard_map(
            _sharded_loop_body(sampler, model, retrain_every, superbatch,
                               controller, telemetry),
            mesh=mesh,
            in_specs=_sharded_in_specs(distributed.AXIS),
            out_specs=_replicated_out_specs(3 if telemetry is None else 4),
        ))
        if telemetry is None:
            return jitted
        return _wrap_run_header(
            jitted, telemetry, scheme=sampler.scheme,
            G=_effective_superbatch(superbatch, retrain_every),
            init=sampler.init, proto_of=item_proto,
        )

    return _memoized(
        "sharded_run_loop",
        (sampler, model, mesh, retrain_every, superbatch, controller,
         telemetry),
        build,
    )


def _replicated_out_specs(n: int = 3):
    from jax.sharding import PartitionSpec as P

    # gathered state / params / trace (+ the drain token under telemetry,
    # identical on every shard) are replicated by construction
    return tuple(P() for _ in range(n))


def _sharded_loop_body(sampler: Sampler, model: ModelAdapter,
                       retrain_every: int,
                       superbatch: int | None = None,
                       controller=None, telemetry=None) -> Callable:
    """Per-shard whole-stream program: superbatched scan of the sharded tick
    (the :func:`_superbatched_scan` skeleton, same chunking contract as
    :func:`_build_run_loop`). With ``telemetry`` the scan drains stats rows
    per shard (the host filters to shard 0 via the axis index)."""
    if controller is None:
        tick, fast = _pair_carry(
            _make_sharded_tick(sampler, model, retrain_every),
            _make_sharded_fast_tick(sampler, model),
        )
    else:
        tick, fast = _make_controlled_sharded_ticks(sampler, model,
                                                    controller, retrain_every)
    G = _effective_superbatch(superbatch, retrain_every)
    if telemetry is None:
        scan = _superbatched_scan(tick, fast, G)
    else:
        stats = _make_loop_stats(sampler, controller, retrain_every)
        if telemetry.resolve_transport() == "fetch":
            # rows ride out as replicated-or-shard-0 outputs (out_spec P())
            scan = _telemetry_fetch_scan(tick, fast, G, telemetry, stats)
        else:
            scan = _telemetry_scan(tick, fast, G, telemetry, stats,
                                   shard_axis=distributed.AXIS)

    def loop(key, batches, bcounts):
        # per-shard views: batch leaves [T, bcap_s, ...], bcounts [T, 1]
        carry0 = (sampler.init(item_proto(batches)), model.init())
        if controller is not None:
            carry0 = carry0 + (controller.init(),)
        if telemetry is None:
            carry, trace = scan(key, carry0, batches, bcounts[:, 0])
            return distributed.gather_tree(carry[0]), carry[1], trace
        carry, trace, aux = scan(key, carry0, batches, bcounts[:, 0])
        return distributed.gather_tree(carry[0]), carry[1], trace, aux

    return loop


def make_sharded_manage_step(sampler: Sampler, model: ModelAdapter, mesh, *,
                             retrain_every: int = 1,
                             controller=None) -> Callable:
    """ONE tick of the sharded loop as its own dispatch: ``(key, t, state,
    params, batch_t, bcount_t) -> (state, params, metrics)``.

    ``state`` is the replicated :func:`~repro.core.distributed.gather_tree`
    snapshot (leading [S] axis on every leaf) -- the same form the fused loop
    returns -- so fused and per-tick runs compose/resume bit-exactly; each
    shard slices its own row back out on entry. ``batch_t`` leaves are
    [S*bcap_s, ...], ``bcount_t`` is [S]. This is the unfused comparison
    point: per-tick dispatch + the snapshot all_gather every tick, which the
    fused scan amortizes away (see benchmarks/manage_loop.py).

    ``controller`` (a :class:`repro.decay.AdaptiveDecay`) threads the
    closed-loop decay controller exactly as in
    :func:`make_sharded_run_loop` -- the signature becomes ``(key, t, state,
    params, cstate, batch_t, bcount_t) -> (state, params, cstate, metrics)``
    with the replicated controller state round-tripped alongside, and the
    per-tick arithmetic (rate -> step_decayed -> observe, adjustment gated
    on retrain ticks) is the SAME controlled tick the fused loop scans, so
    fused and per-tick controlled runs stay bit-identical (asserted in
    tests/test_sharded_loop.py).

    The ``state_g`` snapshot is DONATED on backends that support donation
    (not CPU): the driver round-trips it every dispatch, so donation lets
    XLA reuse the reservoir buffers in place instead of double-buffering
    them -- do not reuse a snapshot after passing it in.
    """
    _check_sharded(sampler)
    if controller is not None:
        _check_controllable(sampler)

    def build():
        from jax.sharding import PartitionSpec as P

        axis = distributed.AXIS
        donate = () if jax.default_backend() == "cpu" else (2,)

        if controller is None:
            tick = _make_sharded_tick(sampler, model, retrain_every)

            def step(key, t, state_g, params, batch_items, bcount):
                me = jax.lax.axis_index(axis)
                state = jax.tree_util.tree_map(lambda a: a[me], state_g)
                state, params, metrics = tick(key, t, state, params,
                                              batch_items, bcount[0])
                return distributed.gather_tree(state), params, metrics

            return jax.jit(distributed.shard_map(
                step, mesh=mesh,
                in_specs=(P(), P(), P(), P(), P(axis), P(axis)),
                out_specs=_replicated_out_specs(),
            ), donate_argnums=donate)

        ctick, _ = _make_controlled_sharded_ticks(sampler, model, controller,
                                                  retrain_every)

        def cstep(key, t, state_g, params, cstate, batch_items, bcount):
            me = jax.lax.axis_index(axis)
            state = jax.tree_util.tree_map(lambda a: a[me], state_g)
            (state, params, cstate), metrics = ctick(
                key, t, (state, params, cstate), batch_items, bcount[0]
            )
            return distributed.gather_tree(state), params, cstate, metrics

        return jax.jit(distributed.shard_map(
            cstep, mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(), P(axis), P(axis)),
            out_specs=(P(), P(), P(), P()),
        ), donate_argnums=donate)

    return _memoized(
        "sharded_manage_step",
        (sampler, model, mesh, retrain_every, controller), build
    )


def make_sharded_run_farm(sampler: Sampler, model: ModelAdapter, mesh, *,
                          retrain_every: int = 1,
                          superbatch: int | None = None,
                          controller=None) -> Callable:
    """Monte-Carlo farm of the sharded loop: ``farm(key, trials, batches,
    bcounts) -> (states, params, trace)`` with a leading [trials] axis on
    every output leaf.

    Trials are ``vmap``-ed INSIDE the shard_map over replicated trial keys
    (one co-partitioned stream shared by all trials), so the collectives
    batch across trials instead of re-entering the mesh per trial -- the
    Fig. 12/13 robustness protocol at cluster scale. ``controller`` threads
    the closed-loop decay controller per trial, as in :func:`make_run_farm`.
    """
    _check_sharded(sampler)
    if controller is not None:
        _check_controllable(sampler)

    def build():
        loop = _sharded_loop_body(sampler, model, retrain_every, superbatch,
                                  controller)

        def farm_shard(keys, batches, bcounts):
            return jax.vmap(lambda k: loop(k, batches, bcounts))(keys)

        run = jax.jit(distributed.shard_map(
            farm_shard, mesh=mesh,
            in_specs=_sharded_in_specs(distributed.AXIS),
            out_specs=_replicated_out_specs(),
        ))

        def farm(key, trials: int, batches, bcounts):
            keys = jax.random.split(key, trials)
            return run(keys, batches, bcounts)

        return farm

    return _memoized(
        "sharded_run_farm",
        (sampler, model, mesh, retrain_every, superbatch, controller),
        build
    )


def make_sharded_resume_loop(sampler: Sampler, model: ModelAdapter, mesh, *,
                             retrain_every: int = 1,
                             superbatch: int | None = None,
                             controller=None) -> Callable:
    """The sharded loop's checkpoint/resume entry point: continue a fused
    sharded run from its replicated :func:`~repro.core.distributed.gather_tree`
    snapshot.

    Returns ``run(key, snapshot, params, batches, bcounts, t0) -> (snapshot,
    params, trace)`` (with ``controller``: ``run(key, snapshot, params,
    cstate, batches, bcounts, t0) -> (snapshot, params, cstate, trace)``):

      * ``snapshot``: the replicated gathered sampler state exactly as the
        fused run / :func:`init_sharded_state` return it (leading [S] axis on
        every leaf; each shard slices its own row back out on entry);
      * ``batches``/``bcounts``: the co-partitioned SEGMENT to consume, laid
        out as for :func:`make_sharded_run_loop`;
      * ``t0``: the global tick index of the segment's first batch -- the
        loop replays ``fold_in(key, t0 + i)``, so running ``[0, T)`` in one
        go and running ``[0, T1) + [T1, T)`` through this entry point are
        bit-identical (asserted in tests/test_sharded_loop.py). ``t0`` must
        be a concrete int and a multiple of the superbatch chunk G (checked
        here; keep checkpoint boundaries on the retrain cadence and this
        holds for free).

    Serialize ``(snapshot, params[, cstate], next_tick)`` with
    :mod:`repro.checkpoint` for durable restarts -- ``launch/train.py``
    wires exactly that for ``--scheme drtbs|dttbs --ckpt-dir``. Memoized
    like the other builders; ``t0`` is a traced operand, so resuming from
    different ticks reuses one compiled program.
    """
    _check_sharded(sampler)
    if controller is not None:
        _check_controllable(sampler)

    def build():
        from jax.sharding import PartitionSpec as P

        G = _effective_superbatch(superbatch, retrain_every)
        axis = distributed.AXIS
        if controller is None:
            tick, fast = _pair_carry(
                _make_sharded_tick(sampler, model, retrain_every),
                _make_sharded_fast_tick(sampler, model),
            )
        else:
            tick, fast = _make_controlled_sharded_ticks(
                sampler, model, controller, retrain_every
            )
        scan = _superbatched_scan(tick, fast, G)

        def body(key, snapshot, params, aux, batches, bcounts, t0):
            me = jax.lax.axis_index(axis)
            state = jax.tree_util.tree_map(lambda a: a[me], snapshot)
            carry0 = (state, params) + aux
            carry, trace = scan(key, carry0, batches, bcounts[:, 0], t0)
            return (distributed.gather_tree(carry[0]),) + carry[1:] + (trace,)

        nout = 3 if controller is None else 4
        jitted = jax.jit(distributed.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(None, axis), P(None, axis), P()),
            out_specs=(P(),) * nout,
        ))

        def run(key, snapshot, params, *rest):
            *aux, batches, bcounts, t0 = rest
            if int(t0) % G:
                raise ValueError(
                    f"resume tick t0={int(t0)} must be a multiple of the "
                    f"superbatch chunk G={G}, or chunk boundaries would "
                    "drift off the retrain cadence"
                )
            return jitted(key, snapshot, params, tuple(aux), batches,
                          bcounts, jnp.int32(t0))

        return run

    return _memoized(
        "sharded_resume_loop",
        (sampler, model, mesh, retrain_every, superbatch, controller),
        build
    )


def init_sharded_state(sampler: Sampler, num_shards: int, proto: Any) -> Any:
    """The t=0 state in the replicated gathered form the per-tick driver
    round-trips: ``sampler.init`` per shard, stacked on a leading [S] axis
    (bit-identical to ``gather_tree`` of S freshly-initialized shards)."""
    state0 = sampler.init(proto)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (num_shards,) + a.shape), state0
    )


def shard_stream(batches: Any, bcounts: jax.Array, num_shards: int, *,
                 bcap_s: int | None = None):
    """Re-pack a :func:`materialize_stream` output into the co-partitioned
    layout the sharded loop consumes.

    Tick t's ``bcounts[t]`` valid items are split contiguously and evenly
    over ``num_shards`` (shard s of tick t gets ``floor(b/S) + (s < b mod S)``
    items -- uneven and empty shards are fine). Returns ``(batches, bcounts)``
    with leaves [T, S*bcap_s, ...] / [T, S] int32, zero-padded per shard
    segment; ``bcap_s`` defaults to the max per-shard count.
    """
    bcounts = np.asarray(bcounts)
    T = bcounts.shape[0]
    S = num_shards
    counts = np.zeros((T, S), np.int32)
    for t in range(T):
        b = int(bcounts[t])
        counts[t] = b // S + (np.arange(S) < b % S)
    need = int(counts.max()) if T else 0
    bcap_s = max(need, 1) if bcap_s is None else bcap_s
    if need > bcap_s:
        raise ValueError(f"per-shard batch {need} exceeds bcap_s={bcap_s}")

    def repack(leaf):
        leaf = np.asarray(leaf)
        out = np.zeros((T, S * bcap_s) + leaf.shape[2:], leaf.dtype)
        for t in range(T):
            off = 0
            for s in range(S):
                c = int(counts[t, s])
                out[t, s * bcap_s:s * bcap_s + c] = leaf[t, off:off + c]
                off += c
        return jnp.asarray(out)

    return (
        jax.tree_util.tree_map(repack, batches),
        jnp.asarray(counts, jnp.int32),
    )


def materialize_stream(stream: Any, T: int, *, batch_size: int | Callable,
                       mode: int | Callable = 0, bcap: int | None = None,
                       fields: tuple[str, ...] = ("x", "y")):
    """Stack ``stream.batch(t, size, mode)`` for t in [0, T) into scan inputs.

    ``batch_size`` / ``mode`` may be ints or ``t -> int`` schedules (compose
    with :func:`repro.data.streams.batch_size_schedule` / ``mode_schedule``).
    Generators returning tuples are zipped into a dict over ``fields``; a
    single-array stream (e.g. token sequences) stays a bare array. Returns
    ``(batches, bcounts)`` with leaves [T, bcap, ...] / [T] int32, batches
    zero-padded up to ``bcap`` (default: the max tick size).
    """
    size_of = batch_size if callable(batch_size) else (lambda t: batch_size)
    mode_of = mode if callable(mode) else (lambda t: mode)
    sizes = [int(size_of(t)) for t in range(T)]
    bcap = max(sizes) if bcap is None else bcap
    if max(sizes) > bcap:
        raise ValueError(f"batch size {max(sizes)} exceeds bcap={bcap}")

    raw = [stream.batch(t, sizes[t], mode_of(t)) for t in range(T)]
    as_dict = isinstance(raw[0], tuple)
    if as_dict:
        raw = [dict(zip(fields, r)) for r in raw]

    def pad_stack(leaves):
        out = np.zeros((T, bcap) + leaves[0].shape[1:], leaves[0].dtype)
        for t, leaf in enumerate(leaves):
            out[t, : leaf.shape[0]] = leaf
        return jnp.asarray(out)

    if as_dict:
        batches = {
            f: pad_stack([r[f] for r in raw]) for f in raw[0]
        }
    else:
        batches = pad_stack(raw)
    return batches, jnp.asarray(sizes, jnp.int32)
