"""The paper's online model-management loop, fused into one compiled program.

This is the connective tissue the headline claim needs (paper Sec. 1, Fig. 2):
maintain a time-biased sample over the stream, periodically retrain a model on
the realized sample, and evaluate/serve the freshest model -- here as a single
``lax.scan`` over stream batches so the whole loop compiles once and never
leaves the device (DESIGN.md Sec. 8):

    for each tick t (scanned):
      1. metric_t = model.evaluate(params, B_t)     # prequential: eval BEFORE
      2. state    = sampler.step(key_t, state, B_t) # the model/sampler see B_t
      3. if (t+1) % retrain_every == 0:
           params = model.fit(key_t', params, sampler.extract(key_t'', state))

Entry points:
  * :func:`make_run_loop`  -- compile the loop once for a (sampler, model,
                              retrain cadence); reuse across streams/seeds.
  * :func:`run_loop`       -- convenience one-shot wrapper.
  * :func:`make_run_farm` / :func:`run_farm` -- ``vmap`` the whole loop over
    Monte-Carlo trials (the paper's Fig. 12/13 robustness protocol: many
    sampler realizations over one stream, metric quantiles over trials).
  * :func:`materialize_stream` -- stack a host-side generator from
    :mod:`repro.data.streams` into the fixed-shape [T, bcap, ...] arrays the
    scan consumes.

Key discipline (bit-exact replays, and what tests assert): tick t uses
``fold_in(key, t)`` split into (step, extract, fit) subkeys, so a fused run,
an unfused per-tick driver, and a checkpoint-resumed run all see identical
randomness.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import Sampler
from repro.manage.models import ModelAdapter


def tick_keys(key: jax.Array, t) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The loop's per-tick (step, extract, fit) keys -- public so unfused
    drivers and tests can reproduce the fused loop exactly."""
    return tuple(jax.random.split(jax.random.fold_in(key, t), 3))


def item_proto(batches: Any) -> Any:
    """ONE-item prototype from stacked stream arrays (leaves [T, bcap, ...])."""
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[2:], a.dtype), batches
    )


def _check_local(sampler: Sampler) -> None:
    if sampler.distributed:
        from repro.core.distributed import AXIS

        raise ValueError(
            f"sampler {sampler.scheme!r} is a per-shard scheme: its step/extract "
            f"must run under jax.shard_map over the {AXIS!r} axis and cannot "
            "drive the single-host manage loop directly"
        )


def make_manage_step(sampler: Sampler, model: ModelAdapter, *,
                     retrain_every: int = 1) -> Callable:
    """One tick of the loop: ``(key, t, state, params, batch, bcount) ->
    (state, params, metrics)``. Composable: this exact function is what
    :func:`make_run_loop` scans, so driving it tick-by-tick (checkpointing,
    serving, human-in-the-loop) stays bit-identical to the fused run."""
    _check_local(sampler)

    def step(key, t, state, params, batch_items, bcount):
        k_step, k_extract, k_fit = tick_keys(key, t)
        metric = model.evaluate(params, batch_items, bcount)
        state = sampler.step(k_step, state, batch_items, bcount)
        view = sampler.extract(k_extract, state)

        do_fit = (t + 1) % retrain_every == 0
        params = jax.lax.cond(
            do_fit,
            lambda: model.fit(k_fit, params, view),
            lambda: params,
        )
        metrics = {"metric": metric, "size": view.size}
        return state, params, metrics

    return step


def make_run_loop(sampler: Sampler, model: ModelAdapter, *,
                  retrain_every: int = 1) -> Callable:
    """Compile the full-stream loop once.

    Returns ``run(key, batches, bcounts) -> (state, params, trace)`` where
    ``batches`` leaves are [T, bcap, ...], ``bcounts`` is [T] int32, and
    ``trace`` holds per-tick {"metric" f32[T], "size" i32[T]}. The whole
    stream is consumed by ONE jitted ``lax.scan`` -- no per-tick dispatch.
    """
    tick = make_manage_step(sampler, model, retrain_every=retrain_every)

    @jax.jit
    def run(key, batches, bcounts):
        state0 = sampler.init(item_proto(batches))
        params0 = model.init()
        T = bcounts.shape[0]

        def body(carry, inp):
            state, params = carry
            t, batch_items, bcount = inp
            state, params, metrics = tick(key, t, state, params,
                                          batch_items, bcount)
            return (state, params), metrics

        (state, params), trace = jax.lax.scan(
            body, (state0, params0),
            (jnp.arange(T, dtype=jnp.int32), batches, bcounts),
        )
        return state, params, trace

    return run


def run_loop(key: jax.Array, sampler: Sampler, model: ModelAdapter,
             batches: Any, bcounts: jax.Array, *, retrain_every: int = 1):
    """One-shot convenience wrapper over :func:`make_run_loop`."""
    return make_run_loop(sampler, model, retrain_every=retrain_every)(
        key, batches, bcounts
    )


def make_run_farm(sampler: Sampler, model: ModelAdapter, *,
                  retrain_every: int = 1) -> Callable:
    """Monte-Carlo farm: ``farm(key, trials, batches, bcounts) -> trace``.

    ``vmap`` of the fused loop over ``trials`` independent sampler/model
    randomness streams sharing one data stream; trace leaves gain a leading
    [trials] axis. This is the Fig. 12/13 robustness protocol (mean + expected
    shortfall over realizations) as one compiled program.
    """
    run = make_run_loop(sampler, model, retrain_every=retrain_every)

    def farm(key, trials: int, batches, bcounts):
        keys = jax.random.split(key, trials)
        _, _, trace = jax.vmap(lambda k: run(k, batches, bcounts))(keys)
        return trace

    return farm


def run_farm(key: jax.Array, trials: int, sampler: Sampler,
             model: ModelAdapter, batches: Any, bcounts: jax.Array, *,
             retrain_every: int = 1):
    """One-shot convenience wrapper over :func:`make_run_farm`."""
    return make_run_farm(sampler, model, retrain_every=retrain_every)(
        key, trials, batches, bcounts
    )


def materialize_stream(stream: Any, T: int, *, batch_size: int | Callable,
                       mode: int | Callable = 0, bcap: int | None = None,
                       fields: tuple[str, ...] = ("x", "y")):
    """Stack ``stream.batch(t, size, mode)`` for t in [0, T) into scan inputs.

    ``batch_size`` / ``mode`` may be ints or ``t -> int`` schedules (compose
    with :func:`repro.data.streams.batch_size_schedule` / ``mode_schedule``).
    Generators returning tuples are zipped into a dict over ``fields``; a
    single-array stream (e.g. token sequences) stays a bare array. Returns
    ``(batches, bcounts)`` with leaves [T, bcap, ...] / [T] int32, batches
    zero-padded up to ``bcap`` (default: the max tick size).
    """
    size_of = batch_size if callable(batch_size) else (lambda t: batch_size)
    mode_of = mode if callable(mode) else (lambda t: mode)
    sizes = [int(size_of(t)) for t in range(T)]
    bcap = max(sizes) if bcap is None else bcap
    if max(sizes) > bcap:
        raise ValueError(f"batch size {max(sizes)} exceeds bcap={bcap}")

    raw = [stream.batch(t, sizes[t], mode_of(t)) for t in range(T)]
    as_dict = isinstance(raw[0], tuple)
    if as_dict:
        raw = [dict(zip(fields, r)) for r in raw]

    def pad_stack(leaves):
        out = np.zeros((T, bcap) + leaves[0].shape[1:], leaves[0].dtype)
        for t, leaf in enumerate(leaves):
            out[t, : leaf.shape[0]] = leaf
        return jnp.asarray(out)

    if as_dict:
        batches = {
            f: pad_stack([r[f] for r in raw]) for f in raw[0]
        }
    else:
        batches = pad_stack(raw)
    return batches, jnp.asarray(sizes, jnp.int32)
