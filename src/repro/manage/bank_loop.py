"""Bank-level model-management loops (DESIGN.md Sec. 13).

The paper's stream -> sample -> retrain -> eval loop, lifted to a
:class:`repro.bank.SamplerBank`: one jitted ``lax.scan`` consumes a KEYED
stream (every tick a ``(keys, payload)`` batch) and maintains K per-key
time-biased samples concurrently. Two retraining regimes:

  * **shared model** (default): one model, periodically retrained on the
    POOLED extract of a key subset (``train_keys``) -- the multi-tenant
    analogue of the paper's single loop, where the model serves all keys
    but the sample it trains on is per-key time-biased;
  * **per-key farm** (``per_key=True``): a ``vmap``-ed model per train key,
    each fit on ITS key's sample and prequentially evaluated on ITS key's
    arrivals (the Fig. 12/13 scenarios replayed per key -- keyed streams
    give every key its own drift phase). Optionally a vmapped
    :func:`repro.decay.loss_ratio` controller per key closes the loop
    between each key's prequential loss and its decay rate, through the
    bank's ``step_decayed`` with a per-key [K] factor vector.

``make_sharded_bank_loop`` splits the KEYS over the mesh instead of the
batch: each shard owns a contiguous key range (its own local bank + model
farm), the stream is co-partitioned by key ownership
(:func:`shard_keyed_stream`), and the only cross-device traffic is the
per-tick psum of the prequential metric -- key-sharded scale-out rides the
same ``shard_map`` skeleton as the Sec.-5 schemes with NO payload
collectives at all.

Superbatching, tick-key discipline, and builder memoization are shared with
:mod:`repro.manage.loop` (same ``tick_keys``, same chunked-scan skeleton,
bit-identical for any G).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank import SamplerBank, route
from repro.core import distributed
from repro.core.api import SampleView
from repro.manage.loop import (
    _effective_superbatch,
    _memoized,
    _psum_metric,
    _superbatched_scan,
    _telemetry_fetch_scan,
    _telemetry_scan,
    _wrap_run_header,
    item_proto,
    tick_keys,
)
from repro.manage.models import ModelAdapter
from repro.obs import probe as _obs_probe

KEY_FIELD = "key"


def _split_keyed(batch: Any):
    """A keyed tick batch is a dict with the ``"key"`` column plus payload
    fields; a SINGLE payload field is unwrapped to its bare leaf (the
    convention bare-batch adapters like the SGD/LM adapter expect)."""
    keys = batch[KEY_FIELD]
    payload = {k: v for k, v in batch.items() if k != KEY_FIELD}
    if len(payload) == 1:
        payload = next(iter(payload.values()))
    return keys, payload


def keyed_item_proto(batches: Any) -> Any:
    """ONE-item payload prototype from stacked keyed-stream arrays (the
    ``"key"`` column excluded)."""
    keys, payload = _split_keyed(batches)
    del keys
    return item_proto(payload)


def pooled_view(view: SampleView) -> SampleView:
    """Flatten a stacked per-key :class:`SampleView` ([Q, cap, ...] leaves)
    into one pooled view ([Q*cap, ...]): the union of the keys' realized
    samples, which mask-weighted model fits consume directly."""
    items = jax.tree_util.tree_map(
        lambda a: a.reshape((-1,) + a.shape[2:]), view.items
    )
    return SampleView(items=items, mask=view.mask.reshape(-1),
                      size=view.size.sum())


def _train_windows(bank: SamplerBank, keys, payload, bcount, train_keys):
    """Each train key's slice of the tick: ``(windows, counts)`` with window
    leaves [Q, bcap, ...] whose first counts[q] rows are that key's arrivals
    (0 when the key did not arrive) -- prefix-valid batches for vmapped
    prequential eval, rows past the count ZEROED (the raw windows are
    slices of the key-sorted batch whose tails belong to OTHER tenants; an
    adapter that ignores ``bcount`` must never see another key's data).
    Recomputes the same :func:`repro.bank.route` the bank step runs
    internally on identical inputs (pure, inside one jitted scan body, so
    XLA's CSE normally merges the two sorts; the bank step's closure does
    not take a precomputed Routing)."""
    r = route(keys, bcount, num_keys=bank.num_keys, bcap=bank.bcap)
    b = r.order.shape[0]
    pos = jnp.clip(jnp.searchsorted(r.touched, train_keys), 0, b - 1)
    found = r.touched[pos] == train_keys
    counts = jnp.where(found, r.counts[pos], 0)
    starts = jnp.where(found, r.starts[pos], 0)
    idx = jnp.clip(
        starts[:, None] + jnp.arange(bank.bcap, dtype=jnp.int32)[None, :],
        0, b - 1,
    )
    valid = jnp.arange(bank.bcap, dtype=jnp.int32)[None, :] < counts[:, None]

    def one(a):
        w = jnp.take(jnp.take(a, r.order, axis=0), idx, axis=0)
        return jnp.where(valid.reshape(valid.shape + (1,) * (w.ndim - 2)),
                         w, jnp.zeros_like(w))

    return jax.tree_util.tree_map(one, payload), counts


def _as_train_keys(train_keys, num_keys: int) -> jnp.ndarray:
    tk = np.asarray(train_keys, np.int32).reshape(-1)
    if tk.shape[0] < 1:
        raise ValueError("train_keys must be a non-empty key list")
    if tk.min() < 0 or tk.max() >= num_keys:
        raise ValueError(
            f"train_keys must lie in [0, {num_keys}); got range "
            f"[{tk.min()}, {tk.max()}] -- the sharded bank loop takes "
            "LOCAL ids (see shard_keyed_stream)"
        )
    return jnp.asarray(tk)


def _memo_key(train_keys) -> tuple:
    return tuple(int(k) for k in np.asarray(train_keys).reshape(-1))


def _make_bank_ticks(bank: SamplerBank, model: ModelAdapter,
                     retrain_every: int, train_keys, per_key: bool,
                     controller, metric_fn: Callable | None = None,
                     with_obs: bool = False) -> tuple[Callable, Callable]:
    """(full, fast) opaque-carry ticks for the bank loop, in the
    :func:`repro.manage.loop._superbatched_scan` contract. The fast tick is
    the full tick minus the retrain conditional and minus any controller
    adjustment (``adjust=False`` arithmetic), so superbatched runs stay
    bit-identical to G=1.

    The ticks step through the bank's ``step_stats`` closures so per-tick
    routing overflow (dropped items) is surfaced as the ``"overflow"``
    metrics column; ``with_obs=True`` additionally diverts the remaining
    routing gauges (touched keys, invalid ids, the applied decay factor)
    into the reserved ``"_obs"`` entry for :func:`_telemetry_scan`."""
    tk = _as_train_keys(train_keys, bank.num_keys)
    Q = tk.shape[0]
    shared_eval = metric_fn or (lambda p, b, c: model.evaluate(p, b, c))

    def eval_and_step(key, t, state, params, cstate, batch, bcount, adjust):
        k_step, k_extract, k_fit = tick_keys(key, t)
        keys_t, payload = _split_keyed(batch)
        if per_key:
            windows, counts = _train_windows(bank, keys_t, payload, bcount,
                                             tk)
            metric = jax.vmap(model.evaluate)(params, windows, counts)
        else:
            metric = shared_eval(params, payload, bcount)
        if controller is None:
            state, bstats = bank.step_stats(k_step, state, keys_t, payload,
                                            bcount)
        elif per_key:
            d_q = jax.vmap(controller.rate)(cstate)
            d_full = jnp.full((bank.num_keys,), bank.base_rate(state),
                              jnp.float32).at[tk].set(d_q)
            state, bstats = bank.step_decayed_stats(k_step, state, keys_t,
                                                    payload, bcount, d_full)
            cstate = jax.vmap(controller.observe, in_axes=(0, 0, None))(
                cstate, metric, adjust
            )
        else:
            d = controller.rate(cstate)
            state, bstats = bank.step_decayed_stats(k_step, state, keys_t,
                                                    payload, bcount, d)
            cstate = controller.observe(cstate, metric, adjust)
        return state, cstate, metric, bstats, (k_extract, k_fit)

    def tick_metrics(k_extract, state, metric, bstats):
        m = {"metric": metric, "size": bank.size(k_extract, state, tk),
             "overflow": bstats["overflow"]}
        if with_obs:
            m["_obs"] = {"ntouched": bstats["ntouched"],
                         "invalid": bstats["invalid"],
                         "decay": bstats["decay"]}
        return m

    def fit(k_extract, k_fit, state, params):
        view = bank.extract(k_extract, state, tk)
        if per_key:
            return jax.vmap(model.fit, in_axes=(0, 0, 0))(
                jax.random.split(k_fit, Q), params, view
            )
        return model.fit(k_fit, params, pooled_view(view))

    def full(key, t, carry, batch, bcount):
        state, params, *cs = carry
        cstate = cs[0] if cs else None
        do_fit = (t + 1) % retrain_every == 0
        state, cstate, metric, bstats, (k_extract, k_fit) = eval_and_step(
            key, t, state, params, cstate, batch, bcount, do_fit
        )
        params = jax.lax.cond(
            do_fit,
            lambda: fit(k_extract, k_fit, state, params),
            lambda: params,
        )
        m = tick_metrics(k_extract, state, metric, bstats)
        out = (state, params) + ((cstate,) if cs else ())
        return out, m

    def fast(key, t, carry, batch, bcount):
        state, params, *cs = carry
        cstate = cs[0] if cs else None
        state, cstate, metric, bstats, (k_extract, _) = eval_and_step(
            key, t, state, params, cstate, batch, bcount, False
        )
        m = tick_metrics(k_extract, state, metric, bstats)
        out = (state, params) + ((cstate,) if cs else ())
        return out, m

    return full, fast


def _make_bank_stats(bank: SamplerBank, controller, per_key: bool,
                     retrain_every: int, probe_key: int) -> Callable:
    """The bank loop's telemetry row (DESIGN.md Sec. 14): per-tick routing
    gauges (touched keys, invalid ids, overflow drops), the probed tenant's
    Thm 4.1 self-check columns (:func:`repro.obs.probe.
    make_bank_probe_stats`), the pending-decay magnitude across the bank
    (min composed factor -- how much deferred decay the laziest key is
    carrying), and the controller gauges (the probe/first train key's lane
    under ``per_key``)."""
    probe = _obs_probe.make_bank_probe_stats(bank, probe_key)
    cstats = getattr(controller, "stats", None)

    def stats_fn(t, batch, bcount, carry, m, obs):
        t = jnp.asarray(t, jnp.int32)
        keys_t, _ = _split_keyed(batch)
        state = carry[0]
        row = {
            "t": t,
            "bcount": jnp.asarray(bcount, jnp.int32),
            "metric": jnp.asarray(m["metric"], jnp.float32),
            "size": jnp.asarray(m["size"], jnp.int32),
            "overflow": jnp.asarray(m["overflow"], jnp.int32),
            "retrain": (t + 1) % retrain_every == 0,
            "ntouched": jnp.asarray(obs["ntouched"], jnp.int32),
            "invalid": jnp.asarray(obs["invalid"], jnp.int32),
        }
        d = jnp.asarray(obs["decay"], jnp.float32)
        # a [K] per-key factor vector reports the probed tenant's lane
        row["decay"] = d if d.ndim == 0 else d[probe_key]
        row.update(probe(state, keys_t, bcount))
        row["pending_min"] = jnp.asarray(state.pending.min(), jnp.float32)
        if cstats is not None:
            cs = carry[2]
            if per_key:
                cs = jax.tree_util.tree_map(lambda a: a[0], cs)
            row.update(cstats(cs))
        return row

    return stats_fn


def _init_carry(bank: SamplerBank, model: ModelAdapter, batches,
                train_keys, per_key: bool, controller):
    Q = _as_train_keys(train_keys, bank.num_keys).shape[0]
    state = bank.init(keyed_item_proto(batches))
    params = model.init()
    if per_key:
        params = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (Q,) + a.shape), params
        )
    carry = (state, params)
    if controller is not None:
        cstate = controller.init()
        if per_key:
            cstate = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    jnp.asarray(a)[None], (Q,) + jnp.asarray(a).shape
                ),
                cstate,
            )
        carry = carry + (cstate,)
    return carry


def make_bank_run_loop(bank: SamplerBank, model: ModelAdapter, *,
                       retrain_every: int = 1, train_keys,
                       per_key: bool = False, superbatch: int | None = None,
                       controller=None, telemetry=None) -> Callable:
    """Compile the keyed-stream management loop once.

    Returns ``run(key, batches, bcounts) -> (state, params, trace)``:

      * ``batches``: a dict with the int32 ``"key"`` column [T, b] plus the
        payload fields (leaves [T, b, ...]) -- the layout
        :func:`repro.manage.materialize_stream` produces for a
        :class:`repro.data.streams.KeyedStream` with
        ``fields=("key", ...)``;
      * ``train_keys``: the key subset that is retrained on / traced (for
        Zipf streams ``range(Q)`` are the popular keys);
      * shared-model mode: ``trace = {"metric" f32[T], "size" i32[T, Q]}``,
        fit consumes the POOLED extract of ``train_keys``;
      * ``per_key=True``: params gain a leading [Q] axis (one model per
        train key), ``trace["metric"]`` is [T, Q] -- each key's prequential
        loss on its own arrivals (NaN on ticks it did not arrive). The
        per-key eval windows are zero-padded ``bcap`` batches with a
        per-key ``bcount``: adapters must honor ``bcount`` for a correct
        metric (all closed-form adapters do; for
        :func:`repro.manage.make_sgd_adapter` pass ``row_loss=`` -- its
        default scalar loss averages the zero padding in, the same caveat
        as the sharded loop's padded shard segments);
      * ``controller``: a :func:`repro.decay.loss_ratio` driven globally
        (shared mode, scalar metric) or vmapped per key (``per_key=True``:
        each key's loss drives its own lambda; untrained keys follow the
        bank's schedule).

    Memoized like :func:`repro.manage.make_run_loop`; ``superbatch`` chunks
    the scan with the same divisor rule, bit-identically.

    ``telemetry``: an optional :class:`repro.obs.Telemetry` handle -- the
    loop drains per-tick records (routing gauges + the probed tenant's
    Thm 4.1 columns, ``telemetry.probe_key`` defaulting to key 0) at
    ``telemetry.every``-tick boundaries, with the SAME ``(state, params,
    trace)`` outputs bit-for-bit as ``telemetry=None``.
    """

    def build():
        G = _effective_superbatch(superbatch, retrain_every)
        full, fast = _make_bank_ticks(bank, model, retrain_every, train_keys,
                                      per_key, controller,
                                      with_obs=telemetry is not None)
        if telemetry is None:
            scan = _superbatched_scan(full, fast, G)
        else:
            pk = 0 if telemetry.probe_key is None else int(telemetry.probe_key)
            stats = _make_bank_stats(bank, controller, per_key, retrain_every,
                                     pk)
            if telemetry.resolve_transport() == "fetch":
                scan = _telemetry_fetch_scan(full, fast, G, telemetry, stats)
            else:
                scan = _telemetry_scan(full, fast, G, telemetry, stats)

        @jax.jit
        def run(key, batches, bcounts):
            carry0 = _init_carry(bank, model, batches, train_keys, per_key,
                                 controller)
            if telemetry is None:
                carry, trace = scan(key, carry0, batches, bcounts)
                return carry[0], carry[1], trace
            carry, trace, aux = scan(key, carry0, batches, bcounts)
            return carry[0], carry[1], trace, aux

        if telemetry is None:
            return run
        return _wrap_run_header(run, telemetry,
                                scheme=f"bank.{bank.scheme}", G=G,
                                init=bank.init, proto_of=keyed_item_proto)

    return _memoized(
        "bank_run_loop",
        (bank, model, retrain_every, _memo_key(train_keys), per_key,
         superbatch, controller, telemetry),
        build,
    )


def make_sharded_bank_loop(bank: SamplerBank, model: ModelAdapter, mesh, *,
                           retrain_every: int = 1, train_keys,
                           per_key: bool = False,
                           superbatch: int | None = None,
                           telemetry=None) -> Callable:
    """The key-sharded bank loop: keys split across devices, zero payload
    collectives.

    ``bank`` is the LOCAL per-shard bank (``num_keys`` = K/S keys, key ids
    localized); ``batches``/``bcounts`` are the co-partitioned keyed stream
    from :func:`shard_keyed_stream` (leaves [T, S*b_s, ...] with shard s
    owning slots [s*b_s, (s+1)*b_s), local key ids; bcounts [T, S]).
    ``train_keys`` are LOCAL ids, the same subset on every shard (each
    shard's models train on its own keys). Per tick the ONLY cross-device
    traffic is the scalar psum of the |B_t|-weighted prequential metric
    (the per-key metrics of ``per_key=True`` stay shard-local); reservoirs,
    routing, payload movement, and fits are all shard-resident.

    Returns ``run(key, batches, bcounts) -> (state, params, trace)`` with
    every output in replicated gathered form (leading [S] axis via
    :func:`repro.core.distributed.gather_tree`): ``state[s]`` is shard s's
    local bank, ``params[s]`` its model (farm), ``trace`` leaves [S, T, ...]
    (the shared-mode metric rows are identical across shards -- it is the
    psum'd global metric).
    """
    from jax.sharding import PartitionSpec as P

    axis = distributed.AXIS

    def build():
        G = _effective_superbatch(superbatch, retrain_every)
        metric_fn = None if per_key else _psum_metric(model)
        full, fast = _make_bank_ticks(bank, model, retrain_every, train_keys,
                                      per_key, None, metric_fn=metric_fn,
                                      with_obs=telemetry is not None)
        if telemetry is None:
            scan = _superbatched_scan(full, fast, G)
        else:
            # the drained columns are shard 0's local view (its bank, its
            # key range); the host keeps only shard 0's stream
            pk = 0 if telemetry.probe_key is None else int(telemetry.probe_key)
            stats = _make_bank_stats(bank, None, per_key, retrain_every, pk)
            if telemetry.resolve_transport() == "fetch":
                scan = _telemetry_fetch_scan(full, fast, G, telemetry, stats)
            else:
                scan = _telemetry_scan(full, fast, G, telemetry, stats,
                                       shard_axis=axis)

        def body(key, batches, bcounts):
            carry0 = _init_carry(bank, model, batches, train_keys, per_key,
                                 None)
            if telemetry is None:
                carry, trace = scan(key, carry0, batches, bcounts[:, 0])
                tail = ()
            else:
                carry, trace, aux = scan(key, carry0, batches, bcounts[:, 0])
                tail = (aux,)
            return tuple(
                distributed.gather_tree(x) for x in (carry[0], carry[1],
                                                     trace)
            ) + tail

        jitted = jax.jit(distributed.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(None, axis), P(None, axis)),
            out_specs=tuple(P() for _ in range(3 if telemetry is None
                                              else 4)),
        ))
        if telemetry is None:
            return jitted
        return _wrap_run_header(jitted, telemetry,
                                scheme=f"bank.{bank.scheme}", G=G,
                                init=bank.init, proto_of=keyed_item_proto)

    return _memoized(
        "sharded_bank_loop",
        (bank, model, mesh, retrain_every, _memo_key(train_keys), per_key,
         superbatch, telemetry),
        build,
    )


def shard_keyed_stream(batches: Any, bcounts, num_shards: int,
                       num_keys: int, *, bcap_s: int | None = None):
    """Re-pack a materialized KEYED stream into the key-ownership layout
    :func:`make_sharded_bank_loop` consumes.

    Keys are split into ``num_shards`` contiguous ranges of
    ``num_keys // num_shards`` (must divide); each tick's valid items move
    into their owning shard's segment (arrival order preserved) with key
    ids LOCALIZED to the shard's range. Returns ``(batches, bcounts)`` with
    leaves [T, S*bcap_s, ...] / [T, S] int32, zero-padded per segment;
    ``bcap_s`` defaults to the max per-shard count.
    """
    if num_keys % num_shards:
        raise ValueError(
            f"num_keys={num_keys} must divide evenly over "
            f"num_shards={num_shards} contiguous key ranges"
        )
    ks = num_keys // num_shards
    keys = np.asarray(batches[KEY_FIELD])
    bcounts = np.asarray(bcounts)
    T = bcounts.shape[0]
    S = num_shards
    owner = np.clip(keys // ks, 0, S - 1)
    counts = np.zeros((T, S), np.int32)
    sel = []
    for t in range(T):
        b = int(bcounts[t])
        rows = [np.nonzero(owner[t, :b] == s)[0] for s in range(S)]
        counts[t] = [len(r) for r in rows]
        sel.append(rows)
    need = int(counts.max()) if T else 0
    bcap_s = max(need, 1) if bcap_s is None else bcap_s
    if need > bcap_s:
        raise ValueError(f"per-shard keyed batch {need} exceeds "
                         f"bcap_s={bcap_s}")

    def repack(leaf, localize=False):
        leaf = np.asarray(leaf)
        out = np.zeros((T, S * bcap_s) + leaf.shape[2:], leaf.dtype)
        for t in range(T):
            for s in range(S):
                rows = sel[t][s]
                seg = leaf[t, rows]
                if localize:
                    seg = seg - s * ks
                out[t, s * bcap_s:s * bcap_s + len(rows)] = seg
        return jnp.asarray(out)

    out = {
        f: repack(v, localize=(f == KEY_FIELD)) for f, v in batches.items()
    }
    return out, jnp.asarray(counts, jnp.int32)
