from . import streams  # noqa: F401
from .streams import (  # noqa: F401
    GMMStream, LinRegStream, UsenetLikeStream, TokenDriftStream,
    batch_size_schedule, mode_schedule,
)
from .pipeline import StreamPipeline  # noqa: F401
