"""Evolving data streams -- the paper's experimental generators (Sec. 6) plus
an LM token stream for the model-zoo driver.

All generators are deterministic functions of (seed, t, mode): replays after a
checkpoint restart are bit-exact, which is the foundation of the fault-
tolerance contract (DESIGN.md Sec. 6).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def mode_schedule(kind: str, t: int, *, delta: int = 10, eta: int = 10,
                  start: int = 10, stop: int = 20) -> int:
    """0 = normal, 1 = abnormal. 'single': abnormal on [start, stop);
    'periodic': delta normal alternating with eta abnormal (paper Sec. 6.2)."""
    if kind == "single":
        return 1 if start <= t < stop else 0
    if kind == "periodic":
        return 1 if (t % (delta + eta)) >= delta else 0
    return 0


def batch_size_schedule(kind: str, t: int, *, b: int = 100, phi: float = 1.002,
                        t0: int = 200, seed: int = 0) -> int:
    """Paper Fig. 1 batch-size regimes: deterministic / growing / uniform /
    decaying."""
    if kind == "constant":
        return b
    if kind in ("growing", "decaying"):
        # Fig. 1(a)/(d): B_{t+1} = phi B_t after t0 (phi > 1 grows, < 1
        # decays). Floored at 1 item: a decaying regime must never reach a
        # permanently-zero bcount tail, which jitted manage loops would spin
        # through as all-NaN empty ticks.
        return max(1, int(round(b * (phi ** max(0, t - t0)))))
    if kind == "uniform":   # Fig. 1(c): iid Uniform[0, 2b]
        return int(np.random.RandomState((seed, t)).randint(0, 2 * b + 1))
    raise ValueError(kind)


@dataclasses.dataclass
class GMMStream:
    """Paper Sec. 6.2: 100 Gaussian-mixture classes on [0,80]^2; 'normal' mode
    makes classes 0..49 five times more frequent, 'abnormal' flips it."""

    seed: int = 0
    num_classes: int = 100
    box: float = 80.0
    sigma: float = 1.0
    ratio: float = 5.0

    def __post_init__(self):
        rs = np.random.RandomState(self.seed)
        self.centroids = rs.uniform(0, self.box, size=(self.num_classes, 2))

    def class_probs(self, mode: int) -> np.ndarray:
        w = np.ones(self.num_classes)
        half = self.num_classes // 2
        if mode == 0:
            w[:half] *= self.ratio
        else:
            w[half:] *= self.ratio
        return w / w.sum()

    def batch(self, t: int, size: int, mode: int):
        """-> (x [size,2] f32, y [size] i32)."""
        rs = np.random.RandomState((self.seed, 7919, t))
        y = rs.choice(self.num_classes, size=size, p=self.class_probs(mode))
        x = self.centroids[y] + rs.normal(0, self.sigma, size=(size, 2))
        return x.astype(np.float32), y.astype(np.int32)


@dataclasses.dataclass
class LinRegStream:
    """Paper Sec. 6.3: y = b1 x1 + b2 x2 + N(0,1); normal (4.2,-0.4),
    abnormal (-3.6, 3.8); x ~ Uniform(0,1)^2."""

    seed: int = 0
    coeffs = ((4.2, -0.4), (-3.6, 3.8))

    def batch(self, t: int, size: int, mode: int):
        rs = np.random.RandomState((self.seed, 104729, t))
        x = rs.uniform(0, 1, size=(size, 2))
        b1, b2 = self.coeffs[mode]
        y = b1 * x[:, 0] + b2 * x[:, 1] + rs.normal(0, 1, size=size)
        return x.astype(np.float32), y.astype(np.float32)


@dataclasses.dataclass
class UsenetLikeStream:
    """Synthetic stand-in for Usenet2 (mlkd.csd.auth.gr is offline-unavailable;
    EXPERIMENTS.md documents the substitution): a stream of bag-of-words
    messages from topic distributions; a simulated user's interest profile
    flips every ``flip_every`` messages (recurring contexts, as in [23])."""

    seed: int = 0
    vocab: int = 100
    topics: int = 4
    words_per_msg: int = 30
    flip_every: int = 300

    def __post_init__(self):
        rs = np.random.RandomState(self.seed)
        self.topic_word = rs.dirichlet(np.ones(self.vocab) * 0.2, self.topics)
        # two interest profiles over topics (which topics the user likes);
        # they OVERLAP on topic 1 so a context flip is a partial inversion
        # (as in Usenet2, where some interests persist across contexts)
        self.profiles = np.array([[1, 1, 0, 0], [0, 1, 1, 0]])

    def message(self, i: int):
        """-> (counts [vocab] f32, label int32 interesting?)."""
        rs = np.random.RandomState((self.seed, 15485863, i))
        topic = rs.randint(self.topics)
        counts = rs.multinomial(self.words_per_msg, self.topic_word[topic])
        profile = (i // self.flip_every) % 2
        label = int(self.profiles[profile][topic])
        return counts.astype(np.float32), np.int32(label)

    def batch(self, t: int, size: int, mode: int = 0):
        del mode  # drift is positional (flip_every), as in the dataset
        xs, ys = zip(*(self.message(t * size + j) for j in range(size)))
        return np.stack(xs), np.asarray(ys, np.int32)


@dataclasses.dataclass
class KeyedStream:
    """Multi-tenant wrapper: stamp any base stream's items with entity keys
    (the :mod:`repro.bank` workload; DESIGN.md Sec. 13).

    Each item gets a key drawn from a Zipf-like popularity law over
    ``num_keys`` entities, ``P(k) ∝ (k + 1)^-alpha`` -- key 0 is the most
    popular, so a bank driver's "top-Q" training subset is simply
    ``range(Q)``. Every key drifts on its OWN phase: key k's mode flips
    every ``flip_every`` ticks with a per-key random offset, so at any tick
    the population is a mixture of both regimes (no global mode argument
    can represent that -- ``batch`` ignores ``mode`` and derives each
    item's regime from its key).  Per-key arrival streams are therefore
    irregular by construction (a rare key skips most ticks), which is what
    exercises the bank's lazy pending decay and the schedules' ``dt`` form.

    ``batch(t, size) -> (keys [size] i32, *payload)`` where payload is the
    base stream's tuple (or single array), rows drawn from the item's
    per-key regime. Deterministic in (seed, t), like every generator here.
    """

    base: object
    num_keys: int
    alpha: float = 1.1
    seed: int = 0
    flip_every: int = 50

    def __post_init__(self):
        w = (1.0 + np.arange(self.num_keys)) ** -float(self.alpha)
        self.key_probs = w / w.sum()
        rs = np.random.RandomState((self.seed, 9973))
        self.phases = rs.randint(0, max(self.flip_every, 1),
                                 size=self.num_keys)

    def key_mode(self, k: np.ndarray, t: int) -> np.ndarray:
        """Key k's regime at tick t: phase-shifted periodic flip."""
        if self.flip_every <= 0:
            return np.zeros_like(np.asarray(k))
        return ((t + self.phases[k]) // self.flip_every) % 2

    def batch(self, t: int, size: int, mode: int = 0):
        del mode  # per-item regime comes from the item's key, see docstring
        rs = np.random.RandomState((self.seed, 60013, t))
        keys = rs.choice(self.num_keys, size=size, p=self.key_probs)
        modes = self.key_mode(keys, t)
        raw0 = self.base.batch(t, size, 0)
        raw1 = self.base.batch(t, size, 1)
        if not isinstance(raw0, tuple):
            raw0, raw1 = (raw0,), (raw1,)
        sel = [
            np.where(modes.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, b, a)
            for a, b in zip(raw0, raw1)
        ]
        return (keys.astype(np.int32), *sel)


@dataclasses.dataclass
class TokenDriftStream:
    """LM stream with concept drift: two synthetic 'languages' = different
    bigram transition matrices over one vocabulary; items are fixed-length
    token sequences. Mode selects the language."""

    seed: int = 0
    vocab: int = 256
    seq_len: int = 64
    branching: int = 8

    def __post_init__(self):
        rs = np.random.RandomState(self.seed)
        self.trans = []
        for m in range(2):
            nxt = rs.randint(0, self.vocab, size=(self.vocab, self.branching))
            self.trans.append(nxt)

    def batch(self, t: int, size: int, mode: int):
        """-> tokens [size, seq_len] int32."""
        rs = np.random.RandomState((self.seed, 32452843, t))
        nxt = self.trans[mode]
        toks = np.zeros((size, self.seq_len), np.int64)
        toks[:, 0] = rs.randint(0, self.vocab, size=size)
        for j in range(1, self.seq_len):
            pick = rs.randint(0, self.branching, size=size)
            toks[:, j] = nxt[toks[:, j - 1], pick]
        return toks.astype(np.int32)
