"""Host-side streaming pipeline: background prefetch with straggler-tolerant
partial-batch assembly.

The assembly tick waits up to ``tick_timeout`` for per-shard producers; shards
that miss the deadline contribute ZERO items this tick and their data is
delivered next tick. R-TBS is provably correct under arbitrary batch-size
fluctuation (paper Thm 4.2 holds for any {B_t}), so stragglers cost freshness,
never statistical correctness -- the paper's robustness theorem doubling as a
straggler-mitigation mechanism (DESIGN.md Sec. 6).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Optional

import numpy as np


class StreamPipeline:
    """Pulls per-shard batches from `make_batch(t, shard)` producers on
    background threads; `next_tick()` returns (per-shard arrays, per-shard
    counts) with zeros for late shards."""

    def __init__(
        self,
        make_batch: Callable[[int, int], np.ndarray],
        *,
        num_shards: int,
        shard_capacity: int,
        item_shape: tuple,
        dtype=np.float32,
        prefetch: int = 2,
        tick_timeout: float = 10.0,
    ):
        self.make_batch = make_batch
        self.num_shards = num_shards
        self.cap = shard_capacity
        self.item_shape = tuple(item_shape)
        self.dtype = dtype
        self.tick_timeout = tick_timeout
        self._queues = [queue.Queue(maxsize=prefetch) for _ in range(num_shards)]
        self._carry: list[Optional[np.ndarray]] = [None] * num_shards
        self._stop = threading.Event()
        self._t_produce = [0] * num_shards
        self._threads = [
            threading.Thread(target=self._producer, args=(s,), daemon=True)
            for s in range(num_shards)
        ]
        self.stats = {"late_shards": 0, "ticks": 0}
        for th in self._threads:
            th.start()

    def _producer(self, shard: int):
        t = 0
        while not self._stop.is_set():
            data = np.asarray(self.make_batch(t, shard))
            while not self._stop.is_set():
                try:
                    self._queues[shard].put(data, timeout=0.2)  # backpressure
                    break
                except queue.Full:
                    continue
            t += 1

    def next_tick(self):
        """-> (items [num_shards, cap, *item_shape], counts [num_shards])."""
        items = np.zeros((self.num_shards, self.cap) + self.item_shape, self.dtype)
        counts = np.zeros((self.num_shards,), np.int32)
        deadline = time.monotonic() + self.tick_timeout
        for s in range(self.num_shards):
            data = self._carry[s]
            self._carry[s] = None
            if data is None:
                try:
                    data = self._queues[s].get(
                        timeout=max(0.0, deadline - time.monotonic())
                    )
                except queue.Empty:
                    self.stats["late_shards"] += 1
                    continue  # straggler: zero items this tick
            n = min(len(data), self.cap)
            if len(data) > self.cap:  # overflow -> carry remainder forward
                self._carry[s] = data[self.cap:]
            items[s, :n] = data[:n]
            counts[s] = n
        self.stats["ticks"] += 1
        return items, counts

    def close(self):
        self._stop.set()
