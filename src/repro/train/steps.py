"""Compiled step functions: train (CE + AdamW, optional gradient-accumulation
microbatching), prefill, decode. These are the programs the multi-pod dry-run
lowers and the roofline analyses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_update
from repro.optim.schedule import cosine_schedule


def make_train_step(api, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    total_steps: int = 100_000, warmup: int = 1000):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1 runs gradient accumulation: the global batch is split on
    the leading axis and scanned, bounding activation memory to one microbatch
    (the knob that fits the 123B train_4k cell on 16 GB chips)."""

    def loss_fn(params, batch):
        return api.loss(params, batch)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape(
                    (microbatches, x.shape[0] // microbatches) + x.shape[1:]
                ),
                batch,
            )
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(carry, mb):
                acc_loss, acc_g = carry
                loss, grads = grads_of(params, mb)
                acc_g = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc_g, grads
                )
                return (acc_loss + loss, acc_g), None

            (loss, gsum), _ = jax.lax.scan(
                body, (jnp.float32(0.0), g0), split
            )
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)

        lr_scale = cosine_schedule(
            opt_state["count"], warmup=warmup, total=total_steps
        )
        params, opt_state, om = adamw_update(
            opt_cfg, grads, opt_state, params, lr_scale
        )
        return params, opt_state, {"loss": loss, **om}

    return train_step


def make_prefill_step(api, max_len: int):
    def prefill_step(params, batch):
        return api.prefill(params, batch, max_len)

    return prefill_step


def make_decode_step(api):
    def decode_step(params, caches, tokens):
        logits, caches = api.decode_step(params, caches, tokens)
        # greedy next token (serving hot loop: logits never leave the device)
        nxt = jnp.argmax(
            logits[:, :, : api.cfg.vocab_size], axis=-1
        ).astype(jnp.int32)
        return nxt, caches

    return decode_step
