"""Profiler hooks: phase scopes inside compiled code, trace spans around it
(DESIGN.md Sec. 14).

Two tools with different scopes of validity:

  * :func:`scope` -- ``jax.named_scope`` labels for code being TRACED
    (sampler step phases, retrain branches): the label lands on the HLO ops
    so profiler timelines and compiled-module dumps attribute device time to
    loop phases. Zero runtime cost (purely a trace-time name-stack push),
    which is why the hot paths keep their scopes unconditionally.
  * :func:`annotation` -- ``jax.profiler.TraceAnnotation`` for HOST-side
    phases (per-tick drivers, checkpoint writes): shows up as host events in
    a captured trace.
  * :func:`profile_span` -- bracket a region with
    ``jax.profiler.start_trace/stop_trace`` writing a TensorBoard-loadable
    trace under ``dir`` (what ``launch/train.py --profile-dir`` wraps
    around its first ``--profile-ticks`` ticks).
"""
from __future__ import annotations

import contextlib

import jax


def scope(name: str):
    """Named scope for jitted phase attribution (trace-time only)."""
    return jax.named_scope(name)


def annotation(name: str):
    """Host-side profiler annotation for un-jitted phases."""
    return jax.profiler.TraceAnnotation(name)


@contextlib.contextmanager
def profile_span(dir: str, *, create_perfetto_link: bool = False):
    """Capture a profiler trace of the enclosed region into ``dir``.

    Exceptions inside the region still stop the trace; a failure to START
    the profiler (e.g. another trace already active) degrades to a no-op
    span rather than killing the run -- profiling must never be the reason
    a training run dies."""
    try:
        jax.profiler.start_trace(dir,
                                 create_perfetto_link=create_perfetto_link)
    except Exception as e:  # pragma: no cover - depends on runtime state
        print(f"[obs] profiler trace unavailable ({e}); continuing unprofiled")
        yield False
        return
    try:
        yield True
    finally:
        jax.profiler.stop_trace()
