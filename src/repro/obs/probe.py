"""In-loop gauge extraction: tiny jit-safe closures that read the paper's
operational quantities out of sampler/bank state (DESIGN.md Sec. 14).

Everything here runs INSIDE the compiled loops, so the contract is strict:
fixed shapes, a handful of scalar gathers per tick, no host interaction.
The host-facing column names match what :mod:`repro.obs.monitors` consumes
(``weight`` = stored fractional mass C, ``total_weight`` = decayed W,
``probe_*`` = the sampled tenant's columns for the Thm 4.1 self-check).
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


def tree_nbytes(tree: Any) -> int:
    """Total buffer bytes of a pytree of arrays or ShapeDtypeStructs -- the
    reservoir-memory gauge ("Succinct Sampling on Streams" motivates
    tracking the actual footprint, PAPERS.md)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        shape = getattr(leaf, "shape", ())
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:
            continue
        total += int(math.prod(shape)) * jnp.dtype(dtype).itemsize
    return total


def state_nbytes(init: Callable, proto: Any) -> int:
    """Reservoir-state bytes of ``init(proto)`` WITHOUT materializing it
    (``jax.eval_shape``), for the run-header gauge."""
    return tree_nbytes(jax.eval_shape(init, proto))


def static_decay(sampler) -> float | None:
    """The per-tick decay factor d = e^{-lambda} when it is a static
    constant (the common exponential schedule), else None. Lets telemetry
    rows carry ``decay`` -- the Thm 4.1 recursion input -- even on loops
    with no controller in the carry."""
    hyper = getattr(sampler, "hyper", None) or {}
    sched = hyper.get("decay")
    rate = getattr(sched, "static_rate", None)
    if rate is not None:
        return float(rate)
    lam = hyper.get("lam")
    if lam is not None:
        return math.exp(-float(lam))
    return None


def make_state_stats(sampler=None) -> Callable[[Any], dict]:
    """Build ``stats(state) -> {column: scalar array}`` for a sampler state
    by structural inspection, covering every scheme family:

      * R-TBS (``RTBSState``): ``weight`` = C (latent mass), ``total_weight``
        = W, ``fill_frac`` = C / n;
      * buffer schemes (``BufferState``: ttbs/btbs/sw/brs): ``weight`` = the
        buffer count, ``overflow_total`` = cumulative capacity drops;
      * distributed shard states: per-shard view of the replicated
        ``weight``/``total_weight`` scalars;
      * time-varying-schedule wrappers (``DecayedState``) are unwrapped.

    Unknown states degrade to an empty dict -- telemetry never makes a
    scheme unusable.
    """
    n = None
    hyper = getattr(sampler, "hyper", None) or {}
    if hyper.get("n"):
        n = int(hyper["n"])

    def stats(state: Any) -> dict:
        inner = getattr(state, "inner", None)
        if inner is not None:  # DecayedState wrapper
            state = inner
        row: dict = {}
        lat = getattr(state, "lat", None)
        weight = None
        if lat is not None:
            weight = lat.weight
        elif getattr(state, "weight", None) is not None:
            weight = state.weight
        elif getattr(state, "count", None) is not None:
            weight = state.count.astype(jnp.float32)
        if weight is not None:
            row["weight"] = jnp.asarray(weight, jnp.float32)
            if n:
                row["fill_frac"] = row["weight"] / jnp.float32(n)
        tw = getattr(state, "total_weight", None)
        if tw is not None:
            row["total_weight"] = jnp.asarray(tw, jnp.float32)
        ov = getattr(state, "overflow", None)
        if ov is not None and getattr(ov, "ndim", 1) == 0:
            row["overflow_total"] = jnp.asarray(ov, jnp.int32)
        return row

    return stats


def make_bank_probe_stats(bank, probe_key: int) -> Callable:
    """Build ``stats(state, keys, bcount) -> {probe_*: scalar}`` for one
    sampled tenant of a :class:`repro.bank.SamplerBank` -- the bank-level
    Thm 4.1 self-check columns.

    ``probe_total_weight`` is the key's EFFECTIVE decayed weight
    W_eff = pending * total_weight (what a standalone sampler fed only this
    key's arrivals would hold), ``probe_arrivals`` the key's accepted
    arrivals this tick (clipped to the routing ``bcap``, matching the
    bank's own W recursion), ``probe_weight`` the effective stored mass
    C_eff, ``probe_overflow`` the key's cumulative drops. The host monitor
    re-integrates W_eff,t = d_t W_eff,t-1 + a_t against these.
    """
    pk = int(probe_key)
    if not 0 <= pk < bank.num_keys:
        raise ValueError(
            f"probe_key must lie in [0, {bank.num_keys}); got {pk}"
        )
    bcap = int(bank.bcap)

    def stats(state, keys: jax.Array, bcount) -> dict:
        b = keys.shape[0]
        valid = jnp.arange(b, dtype=jnp.int32) < jnp.asarray(bcount, jnp.int32)
        arrivals = ((keys.astype(jnp.int32) == pk) & valid).sum()
        w_eff = state.pending[pk] * state.total_weight[pk]
        return {
            "probe_key": jnp.int32(pk),
            "probe_arrivals": jnp.minimum(arrivals, bcap).astype(jnp.int32),
            "probe_total_weight": jnp.asarray(w_eff, jnp.float32),
            "probe_weight": jnp.minimum(
                jnp.asarray(state.weight[pk], jnp.float32), w_eff
            ),
            "probe_pending": jnp.asarray(state.pending[pk], jnp.float32),
            "probe_overflow": jnp.asarray(state.overflow[pk], jnp.int32),
        }

    return stats
