"""Telemetry sinks: where drained records go (DESIGN.md Sec. 14).

A sink consumes one flat JSON-able dict per call. The drain side
(:class:`repro.obs.Telemetry`) batches records at superbatch boundaries, so
sinks are written for bursts: ``emit`` must be cheap per record and any
buffering is flushed by ``flush``/``close``. Three implementations cover the
launch scripts (JSONL files), interactive runs (stdout), and tests/monitors
(an in-memory ring).
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import Any, Iterable, Protocol, runtime_checkable


@runtime_checkable
class Sink(Protocol):
    """The sink contract: ``emit`` one record dict, ``flush`` buffers,
    ``close`` releases resources. Records are flat dicts of JSON-able
    scalars/lists with a ``"kind"`` discriminator (``run`` | ``tick`` |
    ``warning`` | ``query`` | ...)."""

    def emit(self, record: dict) -> None: ...

    def flush(self) -> None: ...

    def close(self) -> None: ...


_PLAIN = (bool, int, float, str, type(None))


def _jsonable(v: Any) -> Any:
    """Coerce numpy/jax scalars and arrays into plain JSON types."""
    if isinstance(v, _PLAIN):
        return v
    if hasattr(v, "tolist"):
        return v.tolist()
    if hasattr(v, "item"):
        return v.item()
    return v


def as_json_record(record: dict) -> dict:
    # fast path: drained tick records arrive pre-converted (bulk `tolist`
    # in Telemetry._drain_cb) -- skip the rebuild on the loop hot path
    for v in record.values():
        if not isinstance(v, _PLAIN):
            return {k: _jsonable(v) for k, v in record.items()}
    return record


class JsonlSink:
    """One JSON record per line, appended to ``path`` (parent directories
    created). Buffered writes, flushed at drain boundaries by the Telemetry
    driver -- NOT per record."""

    def __init__(self, path: str):
        self.path = str(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")

    def emit(self, record: dict) -> None:
        self._fh.write(json.dumps(as_json_record(record)) + "\n")

    def flush(self) -> None:
        self._fh.flush()

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()


class StdoutSink:
    """Compact one-line-per-record printing, for interactive runs. ``kinds``
    optionally restricts which record kinds print (e.g. only warnings)."""

    def __init__(self, kinds: Iterable[str] | None = None, prefix: str = "[obs]"):
        self.kinds = frozenset(kinds) if kinds is not None else None
        self.prefix = prefix

    def emit(self, record: dict) -> None:
        if self.kinds is not None and record.get("kind") not in self.kinds:
            return
        print(f"{self.prefix} {json.dumps(as_json_record(record))}", flush=False)

    def flush(self) -> None:
        import sys

        sys.stdout.flush()

    def close(self) -> None:
        self.flush()


class MemorySink:
    """Bounded in-memory ring of records -- the test/monitor sink.

    ``records`` is the live deque (oldest first); :meth:`by_kind` filters.
    """

    def __init__(self, capacity: int | None = None):
        self.records: deque[dict] = deque(maxlen=capacity)

    def emit(self, record: dict) -> None:
        self.records.append(as_json_record(record))

    def by_kind(self, kind: str) -> list[dict]:
        return [r for r in self.records if r.get("kind") == kind]

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass
