"""Host-side health monitors over the drained telemetry stream
(DESIGN.md Sec. 14).

Each monitor folds one ``kind="tick"`` record at a time and returns zero or
more ``kind="warning"`` records, which the Telemetry driver routes through
the same sinks as the stream itself. Monitors live entirely on the host --
they cost nothing inside the jitted loops and can keep arbitrary rolling
state. The detectors encode the paper's operational claims:

  * :class:`SampleSizeStability` -- R-TBS maximizes expected sample size AND
    sample-size stability (paper Sec. 4/6): conditionally on C_t, |S_t| is
    C_t with the fractional part Bernoulli-realized, so E|S_t| = C_t. The
    monitor compares the rolling mean realized size against the rolling mean
    stored mass and the rolling coefficient of variation against a bound --
    divergence means the realization path is broken or the scheme is being
    driven outside its regime.
  * :class:`InclusionDrift` -- Theorem 4.1 expresses every inclusion
    probability through the decayed total weight W_t, which obeys the exact
    recursion W_t = d_t * W_{t-1} + |B_t|. The monitor re-integrates that
    recursion on the host from the drained per-tick factors and batch sizes
    and compares against the in-loop ``total_weight`` gauge: relative
    divergence is decay-accounting corruption (the normalizer of Thm 4.1's
    inclusion probabilities, so any drift here biases EVERY downstream
    guarantee).
  * :class:`NanAlarm` -- a non-finite prequential metric on a non-empty tick
    (empty ticks legitimately report NaN).
  * :class:`StuckLambda` -- the adaptive controller's stuck-high failure
    mode (repro.decay.adaptive docstring): lambda pinned at the top of its
    clip range for many consecutive adjustments without a fresh pulse.
  * :class:`OverflowAlarm` -- routing/buffer overflow drops observed this
    tick (the bank's per-key ``bcap`` bound discarding arrivals).
"""
from __future__ import annotations

import math
from collections import deque


class Monitor:
    """Base: fold tick records, emit warning dicts. Subclasses implement
    ``observe(record) -> list[dict]``; ``warn(...)`` builds the standard
    warning envelope."""

    name = "monitor"

    def reset(self) -> None:
        pass

    def observe(self, record: dict) -> list[dict]:
        raise NotImplementedError

    def warn(self, record: dict, message: str, **data) -> dict:
        out = {"kind": "warning", "monitor": self.name,
               "t": record.get("t"), "message": message}
        out.update(data)
        return out


class SampleSizeStability(Monitor):
    """Rolling E|S| vs C and coefficient-of-variation check.

    Watches records carrying scalar ``size`` and ``weight`` (the stored
    fractional mass C_eff). Warns when the window's mean |S| deviates from
    the window's mean C by more than ``rtol`` (relative, floored at
    ``atol`` absolute), or when the size CV exceeds ``max_cv`` -- R-TBS
    sample sizes concentrate tightly around C (paper Fig. 5), so a large CV
    flags an unstable realization path.
    """

    name = "sample_size_stability"

    def __init__(self, *, window: int = 32, rtol: float = 0.25,
                 atol: float = 2.0, max_cv: float = 0.5,
                 cooldown: int = 32):
        self.window, self.rtol, self.atol = window, rtol, atol
        self.max_cv, self.cooldown = max_cv, cooldown
        self.reset()

    def reset(self) -> None:
        self.sizes: deque[float] = deque(maxlen=self.window)
        self.weights: deque[float] = deque(maxlen=self.window)
        self._mute = 0

    def observe(self, record: dict) -> list[dict]:
        size, weight = record.get("size"), record.get("weight")
        if not isinstance(size, (int, float)) or weight is None:
            return []
        self.sizes.append(float(size))
        self.weights.append(float(weight))
        if self._mute > 0:
            self._mute -= 1
            return []
        if len(self.sizes) < self.window:
            return []
        ms = sum(self.sizes) / len(self.sizes)
        mw = sum(self.weights) / len(self.weights)
        var = sum((s - ms) ** 2 for s in self.sizes) / len(self.sizes)
        cv = math.sqrt(var) / ms if ms > 0 else 0.0
        out = []
        if abs(ms - mw) > max(self.rtol * max(mw, 1e-9), self.atol):
            out.append(self.warn(
                record, "rolling mean |S| diverged from stored mass C "
                "(E|S_t| = C_t for R-TBS)",
                mean_size=ms, mean_weight=mw, window=self.window,
            ))
        if cv > self.max_cv:
            out.append(self.warn(
                record, "sample-size coefficient of variation above bound",
                cv=cv, mean_size=ms, window=self.window,
            ))
        if out:
            self._mute = self.cooldown
        return out


class InclusionDrift(Monitor):
    """Thm 4.1 self-check: re-integrate W_t = d_t W_{t-1} + |B_t| on the
    host and compare against the in-loop ``total_weight`` gauge.

    For bank telemetry the same recursion runs on the probe key's columns
    (``probe_arrivals`` accumulated against the global factor -- exactly the
    lazy ``pending`` composition the bank defers, so agreement also
    certifies the Thm-4.1 downsample-composition bookkeeping).
    ``warmup`` ticks are consumed before the first comparison (the monitor
    may attach mid-stream after a drain gap).
    """

    name = "inclusion_drift"

    def __init__(self, *, rtol: float = 0.05, warmup: int = 2,
                 cooldown: int = 32):
        self.rtol, self.warmup, self.cooldown = rtol, warmup, cooldown
        self.reset()

    def reset(self) -> None:
        self._w = None
        self._seen = 0
        self._mute = 0

    def observe(self, record: dict) -> list[dict]:
        d = record.get("decay")
        if d is None:
            return []
        probe = "probe_total_weight" in record
        arrivals = record.get("probe_arrivals" if probe else "bcount")
        reported = record.get("probe_total_weight" if probe else
                              "total_weight")
        if arrivals is None or reported is None:
            return []
        if self._w is None:
            # seed the recursion from the loop's own gauge: the monitor can
            # attach at any drain boundary, not just t=0
            self._w = float(reported)
            return []
        self._w = float(d) * self._w + float(arrivals)
        self._seen += 1
        if self._mute > 0:
            self._mute -= 1
            return []
        if self._seen < self.warmup:
            return []
        err = abs(self._w - float(reported)) / max(abs(self._w), 1e-9)
        if err > self.rtol:
            self._mute = self.cooldown
            w = self._w
            self._w = float(reported)  # re-seed so one glitch warns once
            return [self.warn(
                record, "decayed total weight diverged from the Thm 4.1 "
                "recursion W_t = d_t W_{t-1} + |B_t|",
                expected=w, reported=float(reported), rel_err=err,
            )]
        return []


class NanAlarm(Monitor):
    """Non-finite prequential metric while the tick was non-empty."""

    name = "nan_alarm"

    def observe(self, record: dict) -> list[dict]:
        m, b = record.get("metric"), record.get("bcount")
        if m is None:
            return []
        vals = m if isinstance(m, list) else [m]
        bad = any(v is None or not math.isfinite(v) for v in vals)
        if bad and (b is None or b > 0):
            return [self.warn(record, "non-finite metric on non-empty tick",
                              metric=m, bcount=b)]
        return []


class StuckLambda(Monitor):
    """Controller pinned at its upper clip for ``patience`` consecutive
    records with no fresh pulse -- the stuck-high failure mode the
    relaxation leak exists to prevent (repro.decay.adaptive docstring).
    ``lam_max`` (if known) anchors the check; otherwise the running maximum
    observed lambda is used once lambda has actually moved."""

    name = "stuck_lambda"

    def __init__(self, *, patience: int = 64, lam_max: float | None = None,
                 rtol: float = 1e-3):
        self.patience, self.lam_max, self.rtol = patience, lam_max, rtol
        self.reset()

    def reset(self) -> None:
        self._run = 0
        self._lo = math.inf
        self._hi = -math.inf

    def observe(self, record: dict) -> list[dict]:
        lam = record.get("lam")
        if lam is None:
            return []
        lam = float(lam)
        self._lo, self._hi = min(self._lo, lam), max(self._hi, lam)
        top = self.lam_max if self.lam_max is not None else self._hi
        moved = self._hi > self._lo * (1 + self.rtol)
        pinned = lam >= top * (1 - self.rtol) and moved
        if pinned and not record.get("pulse"):
            self._run += 1
        else:
            self._run = 0
        if self._run >= self.patience:
            self._run = 0
            return [self.warn(
                record, "lambda pinned at its upper clip without a fresh "
                "pulse", lam=lam, lam_max=top, patience=self.patience,
            )]
        return []


class OverflowAlarm(Monitor):
    """Routing/buffer overflow drops this tick (items discarded by the
    static per-key ``bcap`` bound)."""

    name = "overflow_alarm"

    def __init__(self, *, cooldown: int = 16):
        self.cooldown = cooldown
        self.reset()

    def reset(self) -> None:
        self._mute = 0

    def observe(self, record: dict) -> list[dict]:
        ov = record.get("overflow")
        if self._mute > 0:
            self._mute -= 1
            return []
        if isinstance(ov, (int, float)) and ov > 0:
            self._mute = self.cooldown
            return [self.warn(record, "overflow drops this tick",
                              overflow=int(ov))]
        return []


def default_monitors(*, lam_max: float | None = None) -> tuple[Monitor, ...]:
    """The standard detector set the launch scripts attach."""
    return (
        SampleSizeStability(),
        InclusionDrift(),
        NanAlarm(),
        StuckLambda(lam_max=lam_max),
        OverflowAlarm(),
    )
