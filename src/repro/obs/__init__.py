"""repro.obs -- in-loop telemetry, profiler hooks, and live sampler health
monitors (DESIGN.md Sec. 14).

The observability layer for the paper's operational claims: per-tick sample
size / fill fraction / fractional mass C / decayed weight W / effective
lambda and controller pulses / retrain events / bank routing stats are
computed INSIDE the jitted manage loops (:mod:`repro.obs.probe`), stacked
on-device, and drained to the host only in whole superbatch blocks --
either fetched as jit outputs after the run or streamed live through a
token-chained ``pure_callback`` (:mod:`repro.obs.telemetry`,
``transport=``) -- fast ticks stay host-sync-free. Drained records run through health monitors
(:mod:`repro.obs.monitors` -- sample-size stability, the Thm 4.1
inclusion-probability self-check, NaN/stuck-lambda/overflow alarms) and fan
out to sinks (:mod:`repro.obs.sinks` -- JSONL / stdout / in-memory).
Profiler hooks live in :mod:`repro.obs.profile`.

Thread a handle through any loop builder::

    tel = obs.make_telemetry("runs/exp1", every=64)
    run = make_run_loop(sampler, model, retrain_every=5, telemetry=tel)

``telemetry=None`` (the default) compiles the historical program,
bit-identically.
"""
from .monitors import (  # noqa: F401
    InclusionDrift,
    Monitor,
    NanAlarm,
    OverflowAlarm,
    SampleSizeStability,
    StuckLambda,
    default_monitors,
)
from .probe import (  # noqa: F401
    make_bank_probe_stats,
    make_state_stats,
    state_nbytes,
    static_decay,
    tree_nbytes,
)
from .profile import annotation, profile_span, scope  # noqa: F401
from .sinks import (  # noqa: F401
    JsonlSink,
    MemorySink,
    Sink,
    StdoutSink,
    as_json_record,
)
from .telemetry import Telemetry, make_telemetry  # noqa: F401
