"""The telemetry driver: on-device accumulation, boundary drains, host
fan-out (DESIGN.md Sec. 14).

A :class:`Telemetry` object is the single handle the manage loops take via
their optional ``telemetry=`` argument. Inside the jitted scans the loops
stack one fixed-shape stats row per tick (a dict of scalar gauges -- see
:mod:`repro.obs.probe`) and hand ``every``-tick blocks of rows to
:meth:`Telemetry._drain_cb` over one of two transports (``transport=``,
see the class docstring): returned as jit outputs and drained after the
run (``"fetch"``), or through a token-chained ``jax.pure_callback`` at
period boundaries while the run executes (``"callback"`` -- the chain
orders the drains; effectful callbacks serialize XLA:CPU thunk execution,
see ``manage/loop.py _telemetry_scan``). Either way fast ticks never touch
the host, and an instrumented run executes under
``jax.transfer_guard_device_to_host`` (asserted in
tests/test_obs.py). On the host each row becomes one
``kind="tick"`` record, runs through the health monitors
(:mod:`repro.obs.monitors`), and fans out -- with any warnings -- to the
sinks (:mod:`repro.obs.sinks`).

The object hashes by identity, so loop builders memoize per telemetry
handle exactly like Samplers/ModelAdapters; ``telemetry=None`` compiles the
historical program, bit-identically.
"""
from __future__ import annotations

from typing import Any, Iterable

import numpy as np

from .monitors import Monitor
from .sinks import Sink


class Telemetry:
    """Telemetry configuration + host-side drain state.

    ``sinks``: where records go; ``every``: the drain period in ticks
    (rounded down to a multiple of the loop's superbatch chunk G, floor one
    chunk); ``monitors``: host detectors folded over every tick record;
    ``probe_key``: the sampled tenant for bank-level Thm 4.1 self-checks
    (default key 0); ``transport``: how drained blocks leave the compiled
    loop -- ``"callback"`` fires the in-scan ``pure_callback`` chain at
    every period boundary (records land while the run executes),
    ``"fetch"`` returns the stacked rows as ordinary jit outputs and drains
    them right after the run (zero host callbacks in the module -- on
    XLA:CPU ANY live host callback serializes thunk execution and costs
    ~35% on the fused hot loop, see ``manage/loop.py _telemetry_scan``),
    and ``"auto"`` (default) picks fetch on the cpu backend, callback
    elsewhere.
    """

    def __init__(self, sinks: Iterable[Sink], *, every: int = 64,
                 monitors: Iterable[Monitor] = (),
                 probe_key: int | None = None, transport: str = "auto"):
        if every < 1:
            raise ValueError(f"drain period must be >= 1 tick; got {every}")
        if transport not in ("auto", "callback", "fetch"):
            raise ValueError(
                "transport must be 'auto', 'callback' or 'fetch'; "
                f"got {transport!r}"
            )
        self.sinks = tuple(sinks)
        self.every = int(every)
        self.monitors = tuple(monitors)
        self.probe_key = probe_key
        self.transport = transport
        self.runs = 0
        self.drains = 0
        self.ticks = 0
        self.queries = 0  # serve-path records (kind="query")

    def resolve_transport(self) -> str:
        """The concrete drain transport for the current backend."""
        if self.transport != "auto":
            return self.transport
        import jax

        return "fetch" if jax.default_backend() == "cpu" else "callback"

    # -- host-side API -----------------------------------------------------
    def open_run(self, meta: dict) -> None:
        """Start-of-run header: reset monitors, emit one ``kind="run"``
        record carrying the run's static facts (scheme, ticks, chunking,
        backend, jax version, reservoir-state bytes)."""
        self.runs += 1
        for mon in self.monitors:
            mon.reset()
        self._fan_out({"kind": "run", "run": self.runs, **meta})
        self.flush()

    def _fan_out(self, record: dict) -> None:
        for s in self.sinks:
            s.emit(record)

    def emit(self, record: dict) -> None:
        """Emit one record directly from host code (per-tick drivers, the
        serve path). ``kind="tick"`` records are folded through the
        monitors; resulting warnings are emitted alongside."""
        if record.get("kind") == "tick":
            self.ticks += 1
            warnings = []
            for mon in self.monitors:
                warnings.extend(mon.observe(record))
            self._fan_out(record)
            for w in warnings:
                self._fan_out(w)
        else:
            if record.get("kind") == "query":
                self.queries += 1
            self._fan_out(record)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        for s in self.sinks:
            s.close()

    # -- the in-scan drain target ------------------------------------------
    def _drain_cb(self, me: Any, rows: dict) -> None:
        """Consume one drained block: ``rows`` is a dict of stacked column
        arrays (leading dim = ticks in the block). ``me`` is the calling
        shard's index under ``shard_map`` (0 on single-host loops): the
        stats columns are replicated-or-shard-0 quantities, so only shard
        0's stream is kept -- every other shard's drain is a no-op.

        This runs on the loop's critical path (a ``pure_callback`` target,
        see ``manage/loop.py _telemetry_scan``), so columns are converted
        in bulk (`tolist`) instead of per-element."""
        if int(me) != 0:
            return
        self.drains += 1
        cols = {k: np.asarray(v).tolist() for k, v in rows.items()}
        names = ("kind", *cols)
        if self.monitors:
            for vals in zip(*cols.values()):
                self.emit(dict(zip(names, ("tick", *vals))))
        else:  # no monitor fold: skip emit's per-record dispatch
            sinks = self.sinks
            for vals in zip(*cols.values()):
                rec = dict(zip(names, ("tick", *vals)))
                self.ticks += 1
                for s in sinks:
                    s.emit(rec)
        self.flush()


def make_telemetry(dir: str | None = None, *, stdout: bool = False,
                   memory: bool = False, every: int = 64,
                   monitors: Iterable[Monitor] | None = None,
                   probe_key: int | None = None,
                   jsonl_name: str = "telemetry.jsonl") -> Telemetry:
    """Convenience constructor for the launch scripts: JSONL under ``dir``
    and/or stdout and/or an in-memory ring, with the default monitor set
    unless ``monitors`` overrides it."""
    from .monitors import default_monitors
    from .sinks import JsonlSink, MemorySink, StdoutSink

    sinks: list[Sink] = []
    if dir is not None:
        import os

        sinks.append(JsonlSink(os.path.join(dir, jsonl_name)))
    if stdout:
        sinks.append(StdoutSink())
    if memory or not sinks:
        sinks.append(MemorySink())
    mons = default_monitors() if monitors is None else tuple(monitors)
    return Telemetry(sinks, every=every, monitors=mons, probe_key=probe_key)
