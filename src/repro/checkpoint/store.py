"""Checkpoint/restart substrate (fault tolerance; paper Sec. 5.1 checkpoints
the sample and system state -- we checkpoint params, optimizer, RNG, step AND
the reservoir).

Layout: <dir>/step_<n>/ with manifest.json (tree structure, shapes, dtypes)
+ leaves.npz. Writes go to a tmp dir then os.replace (atomic publish): a crash
mid-write never corrupts the latest checkpoint. AsyncCheckpointer runs saves
on a background thread (training never blocks on I/O). ``reshard_reservoir``
re-splits D-R-TBS shard states when the data-parallel width changes (elastic
scaling: a lost pod degrades DP width without violating eq. (1) -- per-shard
full-item sets are exchangeable, so re-partitioning item rows preserves all
inclusion probabilities)."""
from __future__ import annotations

import json
import os
import pathlib
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomically write a checkpoint; prune to the newest ``keep``."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp = directory / f".tmp_step_{step}"
    final = directory / f"step_{step}"
    if tmp.exists():
        import shutil

        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(tmp / "leaves.npz", **arrays)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    # prune old steps
    steps = sorted(
        int(p.name.split("_")[1])
        for p in directory.glob("step_*")
        if p.name.split("_")[1].isdigit()
    )
    for s in steps[:-keep]:
        import shutil

        shutil.rmtree(directory / f"step_{s}", ignore_errors=True)
    return str(final)


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.glob("step_*")
        if p.name.split("_")[1].isdigit()
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory, step: int, tree_like: Any) -> Any:
    """Restore into the structure of ``tree_like`` (shapes may be resharded by
    the caller afterwards)."""
    d = pathlib.Path(directory) / f"step_{step}"
    data = np.load(d / "leaves.npz")
    manifest = json.loads((d / "manifest.json").read_text())
    leaves = [data[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    _, treedef = _flatten(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with at-most-one in flight."""

    def __init__(self, directory, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # device->host now

        def work():
            self.last_path = save_checkpoint(
                self.directory, step, host_tree, keep=self.keep
            )

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def reshard_reservoir(items: np.ndarray, nfull: np.ndarray, new_shards: int,
                      cap_s: int):
    """Elastic re-partition of a D-R-TBS reservoir: gather all valid full items
    and round-robin them over ``new_shards`` fixed-capacity shard buffers.
    Full items are exchangeable, so any deterministic re-partition preserves
    every inclusion probability (Theorem 4.2 is per-item marginal)."""
    S_old, cap_old = items.shape[0], items.shape[1]
    rows = [items[s, : int(nfull[s])] for s in range(S_old)]
    allrows = np.concatenate(rows, axis=0) if rows else items[:0, 0]
    out = np.zeros((new_shards, cap_s) + items.shape[2:], items.dtype)
    counts = np.zeros((new_shards,), np.int32)
    for i, row in enumerate(allrows):
        s = i % new_shards
        if counts[s] < cap_s:
            out[s, counts[s]] = row
            counts[s] += 1
    assert counts.sum() == len(allrows), "elastic reshard overflow: raise cap_s"
    return out, counts
