"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (device count is locked on first jax init)."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: pass explicit-Auto ``axis_types``
    where the concept exists (jax >= 0.5), plain mesh otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds the cross-DCN 'pod' axis
    (2 pods = 512 chips). Axes: data = DP/FSDP, model = TP/EP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many host devices exist (tests/benchmarks)."""
    return make_mesh((data, model), ("data", "model"))


def make_data_mesh(shards: int, axis: str = "data"):
    """1-D mesh over the reservoir co-partitioning axis: what the sharded
    manage loop and the D-R-TBS/D-T-TBS shard_map wrappers run on (the axis
    name must match :data:`repro.core.distributed.AXIS`)."""
    return make_mesh((shards,), (axis,))
