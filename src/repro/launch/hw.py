"""Target-hardware constants for the roofline (TPU v5e-class chip, per the
assignment): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI. DCN (cross-pod)
bandwidth is an assumption (100 Gbps-class NIC per 4 chips ~ 3.1 GB/s/chip),
stated here so the multi-pod collective term is reproducible."""

PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (formula uses chips x link_bw)
DCN_BW = 3.1e9               # bytes/s per chip across pods (assumption)
HBM_PER_CHIP = 16 * 2**30    # v5e: 16 GiB
