"""Parse compiled (SPMD-partitioned) HLO text for collective traffic.

``collective_bytes`` sums, per collective family, the PER-DEVICE payload bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (shapes in partitioned HLO are already per-device).
Cross-pod (DCN) collectives are classified by replica groups that span device
ids from different pods.

Byte accounting per op (ring-algorithm convention, factors of (n-1)/n ~ 1):
  all-gather         -> output bytes        (each device receives out - in)
  reduce-scatter     -> input bytes
  all-reduce         -> 2 x input bytes     (reduce-scatter + all-gather)
  all-to-all         -> input bytes
  collective-permute -> input bytes
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)(.*)$"
)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _crosses_pods(line: str, pod_size: int) -> bool:
    m = _GROUPS_RE.search(line)
    if m:
        for grp in re.findall(r"\{([^}]*)\}", m.group(1)):
            ids = [int(x) for x in grp.split(",") if x.strip()]
            if ids and (max(ids) // pod_size) != (min(ids) // pod_size):
                return True
        return False
    m = _IOTA_RE.search(line)
    if m:
        # iota groups [G,S]<=[dims...]: conservative -- if any group's stride
        # pattern spans >= pod_size ids, flag as cross-pod
        g, s = int(m.group(1)), int(m.group(2))
        return s * g > pod_size and s > 1 and (g * s // g) > pod_size
    return False


def collective_stats(hlo_text: str, *, pod_size: int = 1 << 30) -> dict:
    """Returns {op_kind: bytes, ...}, plus 'total', 'dcn' (cross-pod bytes),
    and 'count' per kind."""
    out = defaultdict(float)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_shape, kind, operands, rest = m.groups()
        if f"{kind}-done" in line:
            continue
        if kind == "all-gather":
            size = _shape_bytes(out_shape)
        else:
            size = _shape_bytes(operands)
        if kind == "all-reduce":
            size *= 2
        out[kind] += size
        counts[kind] += 1
        if _crosses_pods(line, pod_size):
            out["dcn"] += size
    out["total"] = sum(v for k, v in out.items() if k != "dcn")
    return {"bytes": dict(out), "counts": dict(counts)}


def duplicate_fusion_ratio(hlo_text: str) -> float:
    """Crude remat/redundancy indicator: fraction of dot ops appearing in
    more than one fusion with identical shapes."""
    dots = re.findall(r"dot\(([^)]*)\)", hlo_text)
    if not dots:
        return 0.0
    uniq = len(set(dots))
    return 1.0 - uniq / len(dots)
