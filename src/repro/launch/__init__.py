"""Launchers: production mesh, multi-pod dry-run, streaming train driver.

NOTE: importing these modules never touches jax device state; meshes are built
inside functions (dryrun.py forces its 512 host devices before any import).
"""
