"""Online model management driver (the paper's loop, lifted to LMs):

  stream -> time-biased sample update -> (drift-triggered | periodic)
  retraining on the current sample -> prequential evaluation -> checkpoint.

The sampler is any scheme from the unified registry (``--scheme rtbs|sw|brs|
btbs|ttbs|drtbs|dttbs``, see :mod:`repro.core.api`); retraining runs through
the :mod:`repro.manage` SGD adapter, so the reservoir update and the whole
retrain inner loop are compiled programs. Runs any `--arch` (reduced
`--preset smoke` configs on CPU; full configs are for real pods). Fault
tolerance: `--resume` restarts bit-exactly from the newest checkpoint
(params, optimizer, reservoir, stream position).

The distributed schemes (paper Sec. 5) run the SAME loop sharded: the driver
builds a ``data``-axis mesh over ``--shards`` devices (re-exec'ing itself
with forced host devices when the host has too few -- the per-pod production
launcher pattern), co-partitions the stream, and runs the whole run as ONE
fused :func:`repro.manage.make_sharded_run_loop` program: co-partitioned
reservoir shards, replicated params, one psum per tick. Checkpoint/resume is
a local-loop feature; the sharded path logs its trace at the end instead.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm_12b \
      --preset smoke --ticks 30 --retrain-every 5 --scheme rtbs
  PYTHONPATH=src python -m repro.launch.train --arch mamba2_370m \
      --preset smoke --ticks 12 --retrain-every 4 --scheme drtbs --shards 8
"""
from __future__ import annotations

import argparse
import math
import os
import sys

import jax
import jax.numpy as jnp

from repro import config as C
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core.api import available_schemes, make_sampler
from repro.data.streams import TokenDriftStream, mode_schedule
from repro.manage import (
    make_sgd_adapter,
    make_sharded_run_loop,
    materialize_stream,
    shard_stream,
)
from repro.models import zoo
from repro.optim import AdamWConfig, adamw_init
from repro.train.steps import make_train_step

DISTRIBUTED_SCHEMES = ("drtbs", "dttbs")


def build_sampler(scheme: str, *, n: int, lam: float, batch_per_tick: int,
                  shards: int = 1):
    """Map the driver's knobs onto each scheme's hyperparameters."""
    if scheme == "rtbs":
        return make_sampler("rtbs", n=n, lam=lam)
    if scheme in ("sw", "brs"):
        return make_sampler(scheme, n=n)
    if scheme == "btbs":
        # B-TBS has NO size control (paper Alg. 4): steady-state E|S| is
        # b/(1-e^-lam), not --reservoir. Provision 3x that so the capacity
        # bound never silently distorts the time bias.
        steady = batch_per_tick / max(1.0 - math.exp(-lam), 1e-6)
        return make_sampler("btbs", lam=lam, cap=max(n, int(3 * steady) + 1))
    if scheme == "ttbs":
        return make_sampler("ttbs", n=n, lam=lam, batch_size=batch_per_tick)
    if scheme == "drtbs":
        # cap_s covers the worst transient: every global full item plus this
        # shard's incoming batch landing on one shard before the downsample
        return make_sampler("drtbs", n=n, lam=lam, cap_s=n + batch_per_tick)
    if scheme == "dttbs":
        # per-shard targets: n/S sample rows fed by b/S arrivals per shard
        n_s = max(1, -(-n // shards))
        b_s = max(1.0, batch_per_tick / shards)
        return make_sampler("dttbs", n=n_s, lam=lam, batch_size=b_s)
    raise ValueError(f"unsupported scheme {scheme!r}; see {available_schemes()}")


def run_sharded(args, adapter, stream, sampler):
    """The Sec.-5 path: the whole run as ONE fused sharded-loop program.

    Co-partitions every tick's batch over the ``data`` mesh, then executes
    stream -> per-shard sample update -> periodic retrain on the global view
    -> prequential eval as a single jitted scan (no per-tick dispatch, no
    checkpoint round-trips -- the trace is logged after the run).
    """
    from repro.launch.mesh import make_data_mesh

    S = args.shards
    # main() already rounded batch_per_tick up to a multiple of S (the
    # sampler's rates and the padding-free shard segments both depend on it)
    assert args.batch_per_tick % S == 0

    def mode_of(t):
        return 0 if args.drift == "none" else mode_schedule(args.drift, t)

    batches, bcounts = materialize_stream(stream, args.ticks,
                                          batch_size=args.batch_per_tick,
                                          mode=mode_of)
    batches, bcounts = shard_stream(batches, bcounts, S)

    mesh = make_data_mesh(S)
    run = make_sharded_run_loop(sampler, adapter, mesh,
                                retrain_every=args.retrain_every,
                                superbatch=args.superbatch)
    print(f"[train] sharded {args.scheme} loop: {S} shards, "
          f"{args.ticks} ticks, one fused program", flush=True)
    state, model_state, trace = run(jax.random.key(args.seed), batches,
                                    bcounts)
    metric = jax.device_get(trace["metric"])
    size = jax.device_get(trace["size"])
    log = []
    for t in range(args.ticks):
        log.append({"tick": t, "mode": mode_of(t),
                    "eval_loss": float(metric[t]),
                    "sample_size": int(size[t])})
        print(f"[train] tick={t:4d} mode={mode_of(t)} "
              f"eval={float(metric[t]):7.4f} |S|={int(size[t]):5d}",
              flush=True)
    if args.ckpt_dir:
        print("[train] note: checkpoint/resume is a local-loop feature; "
              "the fused sharded run completed in one program")
    return log


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_12b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--scheme", default="rtbs",
                    choices=["rtbs", "sw", "brs", "btbs", "ttbs",
                             "drtbs", "dttbs"])
    ap.add_argument("--shards", type=int, default=8,
                    help="data-axis width for the distributed schemes")
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--batch-per-tick", type=int, default=32)
    ap.add_argument("--reservoir", type=int, default=256)
    ap.add_argument("--lam", type=float, default=0.07)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--retrain-every", type=int, default=5)
    ap.add_argument("--superbatch", type=int, default=None,
                    help="manage-loop chunk size G (divisor of "
                         "--retrain-every; default: 8 on TPU, 1 elsewhere "
                         "-- DESIGN.md Sec. 11)")
    ap.add_argument("--retrain-steps", type=int, default=8)
    ap.add_argument("--train-batch", type=int, default=16)
    ap.add_argument("--drift", default="periodic", choices=["periodic", "single", "none"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)

    if args.scheme in DISTRIBUTED_SCHEMES:
        flag = "--xla_force_host_platform_device_count"
        if jax.device_count() < args.shards:
            if argv is None and flag not in os.environ.get("XLA_FLAGS", ""):
                # same pattern as examples/distributed_reservoir.py and the
                # per-pod production launcher: the devices must exist before
                # jax initializes, so re-exec with the flag set
                os.environ["XLA_FLAGS"] = (
                    os.environ.get("XLA_FLAGS", "") + f" {flag}={args.shards}"
                ).strip()
                os.execv(sys.executable, [sys.executable] + sys.argv)
            args.shards = jax.device_count()  # programmatic call: clamp
        # pad the tick batch to a multiple of the mesh BEFORE the sampler is
        # built: dttbs calibrates its acceptance rates p/q on the per-shard
        # arrival rate, and the SGD adapter's LM loss needs padding-free
        # shard segments (see run_sharded)
        b = -(-args.batch_per_tick // args.shards) * args.shards
        if b != args.batch_per_tick:
            print(f"[train] batch-per-tick {args.batch_per_tick} -> {b} "
                  f"(multiple of {args.shards} shards)")
            args.batch_per_tick = b

    cfg = (C.get_smoke_config(args.arch) if args.preset == "smoke"
           else C.get_config(args.arch))
    api = zoo.build(cfg)
    stream = TokenDriftStream(seed=args.seed, vocab=cfg.vocab_size,
                              seq_len=args.seq_len)

    # fixed schedule horizon: must NOT depend on --ticks, or an interrupted
    # run would train under a different LR curve than the run it resumes
    adapter = make_sgd_adapter(
        init_params=lambda: api.init_params(jax.random.key(args.seed)),
        train_step=make_train_step(
            api, AdamWConfig(lr=args.lr), microbatches=1,
            warmup=2, total_steps=4000,
        ),
        init_opt_state=adamw_init,
        loss=api.loss,
        batch_field="tokens",
        train_batch=args.train_batch,
        retrain_steps=args.retrain_steps,
        name=args.arch,
    )
    sampler = build_sampler(args.scheme, n=args.reservoir, lam=args.lam,
                            batch_per_tick=args.batch_per_tick,
                            shards=args.shards)
    if args.scheme in DISTRIBUTED_SCHEMES:
        return run_sharded(args, adapter, stream, sampler)

    fit = jax.jit(adapter.fit)
    eval_fn = jax.jit(adapter.evaluate)
    proto = jax.ShapeDtypeStruct((args.seq_len,), jnp.int32)
    st = sampler.init(proto)
    model_state = adapter.init()
    start_tick = 0

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            tree = restore_checkpoint(
                args.ckpt_dir, last, (model_state, st, 0)
            )
            model_state, st, start_tick = tree
            model_state = jax.tree_util.tree_map(jnp.asarray, model_state)
            st = jax.tree_util.tree_map(jnp.asarray, st)
            start_tick = int(start_tick)
            print(f"[train] resumed from step {last} (tick {start_tick})")

    log = []
    for t in range(start_tick, args.ticks):
        mode = 0 if args.drift == "none" else mode_schedule(args.drift, t)
        batch = jnp.asarray(stream.batch(t, args.batch_per_tick, mode))

        # prequential eval BEFORE the model sees this data
        eval_loss = float(eval_fn(model_state, batch, args.batch_per_tick))

        # sample update (the paper's technique)
        key_t = jax.random.fold_in(jax.random.key(args.seed + 1), t)
        st = sampler.step(key_t, st, batch, jnp.int32(args.batch_per_tick))

        # ONE realization per tick: the logged |S| is the sample fit trains on
        k_ex, k_fit = jax.random.split(
            jax.random.fold_in(jax.random.key(args.seed + 2), t)
        )
        view = sampler.extract(k_ex, st)
        size = int(view.size)

        # periodic retraining on the realized time-biased sample
        train_loss = float("nan")
        if (t + 1) % args.retrain_every == 0 and size >= args.train_batch:
            model_state = fit(k_fit, model_state, view)
            train_loss = float(
                eval_fn(model_state, batch, args.batch_per_tick)
            )

        # every scheme's state carries W_t (decayed weight for rtbs/ttbs/btbs,
        # item count for brs/sw)
        total_w = float(st.total_weight)
        log.append({"tick": t, "mode": mode, "eval_loss": eval_loss,
                    "train_loss": train_loss, "sample_size": size,
                    "total_weight": total_w})
        print(f"[train] tick={t:4d} mode={mode} eval={eval_loss:7.4f} "
              f"train={train_loss:7.4f} |S|={size:5d} W={total_w:8.2f}",
              flush=True)

        if ckpt and (t + 1) % args.ckpt_every == 0:
            ckpt.save(t + 1, (model_state, st, t + 1))
    if ckpt:
        ckpt.wait()
    return log


if __name__ == "__main__":
    main()
