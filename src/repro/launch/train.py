"""Online model management driver (the paper's loop, lifted to LMs):

  stream -> time-biased sample update -> (drift-triggered | periodic)
  retraining on the current sample -> prequential evaluation -> checkpoint.

The sampler is any scheme from the unified registry (``--scheme rtbs|sw|brs|
btbs|ttbs|drtbs|dttbs``, see :mod:`repro.core.api`); retraining runs through
the :mod:`repro.manage` SGD adapter, so the reservoir update and the whole
retrain inner loop are compiled programs. Runs any `--arch` (reduced
`--preset smoke` configs on CPU; full configs are for real pods). Fault
tolerance: `--resume` restarts bit-exactly from the newest checkpoint
(params, optimizer, reservoir, stream position).

The distributed schemes (paper Sec. 5) run the SAME loop sharded: the driver
builds a ``data``-axis mesh over ``--shards`` devices (re-exec'ing itself
with forced host devices when the host has too few -- the per-pod production
launcher pattern), co-partitions the stream, and runs the whole run as ONE
fused :func:`repro.manage.make_sharded_run_loop` program: co-partitioned
reservoir shards, replicated params, one psum per tick. With ``--ckpt-dir``
the stream is consumed in ``--ckpt-every``-tick segments through
:func:`repro.manage.make_sharded_resume_loop` (the ``gather_tree`` snapshot
is what gets serialized), so ``--resume`` restarts the sharded run
bit-exactly too.

Decay (DESIGN.md Sec. 12): ``--decay exp`` (default; rate ``--lam``) or
``--decay poly`` (power-law, exponent ``--beta``); ``--adaptive`` switches to
the closed-loop controller (lambda driven by the prequential loss between
``--lam-min`` and ``--lam-max``, starting at ``--lam``).

Multi-tenant mode (DESIGN.md Sec. 13): ``--num-keys K`` swaps the single
sampler for a :class:`repro.bank.SamplerBank` -- K per-key time-biased
samples over a Zipf-keyed token stream with per-key drift phases, advanced
by the bank's fused key-routed step; the LM retrains on the pooled extract
of the ``--train-keys`` most popular keys (rtbs/ttbs only).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm_12b \
      --preset smoke --ticks 30 --retrain-every 5 --scheme rtbs
  PYTHONPATH=src python -m repro.launch.train --arch mamba2_370m \
      --preset smoke --ticks 20 --scheme rtbs --num-keys 4096 --train-keys 8
  PYTHONPATH=src python -m repro.launch.train --arch mamba2_370m \
      --preset smoke --ticks 12 --retrain-every 4 --scheme drtbs --shards 8
  PYTHONPATH=src python -m repro.launch.train --arch mamba2_370m \
      --preset smoke --ticks 30 --scheme rtbs --adaptive
"""
from __future__ import annotations

import argparse
import contextlib
import math
import os
import sys

import jax
import jax.numpy as jnp

from repro import config as C
from repro import decay as dk
from repro.bank import make_bank
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core.api import available_schemes, make_sampler
from repro.data.streams import KeyedStream, TokenDriftStream, mode_schedule
from repro.manage import (
    init_sharded_state,
    make_bank_run_loop,
    make_sgd_adapter,
    make_sharded_resume_loop,
    make_sharded_run_loop,
    materialize_stream,
    shard_stream,
)
from repro.models import zoo
from repro.obs import make_telemetry, profile_span
from repro.obs import probe as obs_probe
from repro.optim import AdamWConfig, adamw_init
from repro.train.steps import make_train_step

DISTRIBUTED_SCHEMES = ("drtbs", "dttbs")
DECAY_FREE_SCHEMES = ("sw", "brs")


def build_sampler(scheme: str, *, n: int, lam: float, batch_per_tick: int,
                  shards: int = 1, decay=None):
    """Map the driver's knobs onto each scheme's hyperparameters. ``decay``
    (a DecaySchedule) replaces the scalar ``lam`` when given; ``lam`` still
    sizes the B-TBS capacity bound (a rough steady-state proxy for
    time-varying schedules)."""
    dkw = {"lam": lam} if decay is None else {"decay": decay}
    if scheme == "rtbs":
        return make_sampler("rtbs", n=n, **dkw)
    if scheme in DECAY_FREE_SCHEMES:
        return make_sampler(scheme, n=n)
    if scheme == "btbs":
        # B-TBS has NO size control (paper Alg. 4): steady-state E|S| is
        # b/(1-e^-lam), not --reservoir. Provision 3x that so the capacity
        # bound never silently distorts the time bias.
        steady = batch_per_tick / max(1.0 - math.exp(-lam), 1e-6)
        return make_sampler("btbs", cap=max(n, int(3 * steady) + 1), **dkw)
    if scheme == "ttbs":
        return make_sampler("ttbs", n=n, batch_size=batch_per_tick, **dkw)
    if scheme == "drtbs":
        # cap_s covers the worst transient: every global full item plus this
        # shard's incoming batch landing on one shard before the downsample
        return make_sampler("drtbs", n=n, cap_s=n + batch_per_tick, **dkw)
    if scheme == "dttbs":
        # per-shard targets: n/S sample rows fed by b/S arrivals per shard
        n_s = max(1, -(-n // shards))
        b_s = max(1.0, batch_per_tick / shards)
        return make_sampler("dttbs", n=n_s, batch_size=b_s, **dkw)
    raise ValueError(f"unsupported scheme {scheme!r}; see {available_schemes()}")


def build_decay(args):
    """(DecaySchedule | None for the lam sugar, AdaptiveDecay | None)."""
    if args.scheme in DECAY_FREE_SCHEMES:
        if args.adaptive or args.decay != "exp":
            raise SystemExit(
                f"--scheme {args.scheme} has no decay to configure"
            )
        return None, None
    controller = None
    if args.adaptive:
        lam_min = args.lam_min if args.lam_min is not None else args.lam / 20
        lam_max = args.lam_max if args.lam_max is not None else \
            min(1.5, args.lam * 20)
        controller = dk.loss_ratio(lam0=args.lam, lam_min=lam_min,
                                   lam_max=lam_max)
    sched = None
    if args.decay == "poly":
        sched = dk.polynomial(args.beta)
    return sched, controller


def build_telemetry(args):
    """The run's :class:`repro.obs.Telemetry` handle from the CLI knobs
    (None when telemetry is off -- the loops then compile the historical,
    drain-free programs)."""
    if not (args.telemetry_dir or args.telemetry_stdout):
        return None
    return make_telemetry(args.telemetry_dir, stdout=args.telemetry_stdout,
                          every=args.telemetry_every)


def profile_cm(args):
    """A profiler span over whatever it wraps: the whole fused program, or
    the first ``--profile-ticks`` ticks of the per-tick driver."""
    if not args.profile_dir:
        return contextlib.nullcontext()
    return profile_span(args.profile_dir)


def _log_sharded_trace(trace, t0, mode_of, log, telemetry=None):
    metric = jax.device_get(trace["metric"])
    size = jax.device_get(trace["size"])
    dec = jax.device_get(trace["decay"]) if "decay" in trace else None
    for i in range(len(size)):
        t = t0 + i
        row = {"tick": t, "mode": mode_of(t), "eval_loss": float(metric[i]),
               "sample_size": int(size[i])}
        extra = ""
        if dec is not None:
            row["lam"] = float(-math.log(max(float(dec[i]), 1e-30)))
            extra = f" lam={row['lam']:6.4f}"
        log.append(row)
        if telemetry is not None:  # ckpt-segmented path: host-side records
            telemetry.emit({"kind": "tick", "t": t,
                            "metric": float(metric[i]),
                            "size": int(size[i]),
                            **({"decay": float(dec[i])}
                               if dec is not None else {})})
        print(f"[train] tick={t:4d} mode={mode_of(t)} "
              f"eval={float(metric[i]):7.4f} |S|={int(size[i]):5d}{extra}",
              flush=True)
    if telemetry is not None:
        telemetry.flush()


def run_sharded(args, adapter, stream, sampler, controller=None):
    """The Sec.-5 path: the run as fused sharded-loop program(s).

    Co-partitions every tick's batch over the ``data`` mesh, then executes
    stream -> per-shard sample update -> periodic retrain on the global view
    -> prequential eval as jitted scans (no per-tick dispatch). Without
    ``--ckpt-dir`` the whole stream is ONE program; with it, the stream is
    consumed in ``--ckpt-every``-tick segments through the resume entry
    point (:func:`repro.manage.make_sharded_resume_loop`), serializing the
    replicated ``gather_tree`` snapshot after each segment -- ``--resume``
    restarts bit-exactly (segmented and unsegmented runs produce identical
    traces; tests/test_sharded_loop.py asserts the equivalence).
    """
    from repro.launch.mesh import make_data_mesh

    S = args.shards
    # main() already rounded batch_per_tick up to a multiple of S (the
    # sampler's rates and the padding-free shard segments both depend on it)
    assert args.batch_per_tick % S == 0

    def mode_of(t):
        return 0 if args.drift == "none" else mode_schedule(args.drift, t)

    batches, bcounts = materialize_stream(stream, args.ticks,
                                          batch_size=args.batch_per_tick,
                                          mode=mode_of)
    batches, bcounts = shard_stream(batches, bcounts, S)
    mesh = make_data_mesh(S)
    key = jax.random.key(args.seed)
    log = []

    telemetry = build_telemetry(args)
    if not args.ckpt_dir:
        run = make_sharded_run_loop(sampler, adapter, mesh,
                                    retrain_every=args.retrain_every,
                                    superbatch=args.superbatch,
                                    controller=controller,
                                    telemetry=telemetry)
        print(f"[train] sharded {args.scheme} loop: {S} shards, "
              f"{args.ticks} ticks, one fused program", flush=True)
        with profile_cm(args):
            _, _, trace = run(key, batches, bcounts)
        _log_sharded_trace(trace, 0, mode_of, log)
        if telemetry is not None:
            telemetry.close()
        return log

    # checkpointed: ckpt_every-tick segments through the resume entry point
    seg = -(-args.ckpt_every // args.retrain_every) * args.retrain_every
    resume = make_sharded_resume_loop(sampler, adapter, mesh,
                                      retrain_every=args.retrain_every,
                                      superbatch=args.superbatch,
                                      controller=controller)
    from repro.manage.loop import item_proto

    state = init_sharded_state(sampler, S, item_proto(batches))
    params = adapter.init()
    cstate = controller.init() if controller is not None else None
    start_tick = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir)
    if args.resume:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            like = (state, params, cstate, 0) if controller is not None \
                else (state, params, 0)
            tree = restore_checkpoint(args.ckpt_dir, last, like)
            tree = jax.tree_util.tree_map(jnp.asarray, tree[:-1]) + (tree[-1],)
            if controller is not None:
                state, params, cstate = tree[:-1]
            else:
                state, params = tree[:-1]
            start_tick = int(tree[-1])
            print(f"[train] resumed sharded run from step {last} "
                  f"(tick {start_tick})")
    print(f"[train] sharded {args.scheme} loop: {S} shards, "
          f"{args.ticks} ticks, {seg}-tick checkpointed segments", flush=True)
    if telemetry is not None:
        telemetry.open_run({"scheme": args.scheme, "ticks": args.ticks,
                            "segment": seg, "every": telemetry.every,
                            "backend": jax.default_backend(),
                            "jax": jax.__version__, "state_bytes": None})

    def cut(tree, lo, hi):
        return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)

    for t0 in range(start_tick, args.ticks, seg):
        t1 = min(t0 + seg, args.ticks)
        if controller is not None:
            state, params, cstate, trace = resume(
                key, state, params, cstate, cut(batches, t0, t1),
                bcounts[t0:t1], t0)
            snap = (state, params, cstate, t1)
        else:
            state, params, trace = resume(
                key, state, params, cut(batches, t0, t1), bcounts[t0:t1], t0)
            snap = (state, params, t1)
        _log_sharded_trace(trace, t0, mode_of, log, telemetry=telemetry)
        # only retrain-cadence-aligned ticks are valid resume points (the
        # resume loop requires t0 % G == 0 and G | retrain_every): skip a
        # misaligned final partial segment -- a later --resume with more
        # --ticks restarts from the last aligned save and replays the few
        # tail ticks bit-exactly instead of failing the alignment check
        if t1 % args.retrain_every == 0:
            ckpt.save(t1, snap)
    ckpt.wait()
    if telemetry is not None:
        telemetry.close()
    return log


def run_bank(args, adapter, cfg):
    """Multi-tenant mode (``--num-keys``, DESIGN.md Sec. 13): one
    :class:`repro.bank.SamplerBank` maintains a per-key time-biased sample
    for every entity; the shared LM retrains on the pooled extract of the
    ``--train-keys`` most popular keys. The whole run is one fused
    :func:`repro.manage.make_bank_run_loop` scan over a Zipf-keyed token
    stream with per-key drift phases."""
    if args.ckpt_dir or args.resume:
        raise SystemExit(
            "--num-keys has no checkpoint/resume path yet (ROADMAP bank "
            "follow-up (c)); drop --ckpt-dir/--resume for bank runs"
        )
    K, Q = args.num_keys, min(args.train_keys, args.num_keys)
    stream = KeyedStream(
        base=TokenDriftStream(seed=args.seed, vocab=cfg.vocab_size,
                              seq_len=args.seq_len),
        num_keys=K, seed=args.seed,
        flip_every=0 if args.drift == "none" else 5 * args.retrain_every,
    )
    batches, bcounts = materialize_stream(
        stream, args.ticks, batch_size=args.batch_per_tick,
        fields=("key", "tokens"),
    )
    bcap = args.bank_bcap or args.batch_per_tick
    dkw = {"lam": args.lam}
    sched, controller = build_decay(args)
    if controller is not None:
        raise SystemExit("--adaptive drives per-key farms "
                         "(manage.make_bank_run_loop(per_key=True)); the "
                         "shared-model --num-keys driver runs the bank's "
                         "own schedule")
    if sched is not None:
        dkw = {"decay": sched}
    if args.scheme == "rtbs":
        bank = make_bank("rtbs", num_keys=K, n=args.reservoir, bcap=bcap,
                         **dkw)
    elif args.scheme == "ttbs":
        # per-key mean arrivals per touched tick ~ 1 sub-batch row; the
        # popular keys see ~ b * P(0) of the tick
        bank = make_bank("ttbs", num_keys=K, n=args.reservoir,
                         batch_size=max(1.0, args.batch_per_tick / K),
                         bcap=bcap, **dkw)
    else:
        raise SystemExit(
            f"--num-keys supports the local time-biased schemes rtbs/ttbs; "
            f"got --scheme {args.scheme}"
        )
    telemetry = build_telemetry(args)
    run = make_bank_run_loop(bank, adapter, retrain_every=args.retrain_every,
                             train_keys=range(Q),
                             superbatch=args.superbatch,
                             telemetry=telemetry)
    print(f"[train] bank {args.scheme} loop: K={K} keys, top-{Q} trained, "
          f"{args.ticks} ticks, one fused program", flush=True)
    with profile_cm(args):
        state, _, trace = run(jax.random.key(args.seed), batches, bcounts)
    metric = jax.device_get(trace["metric"])
    sizes = jax.device_get(trace["size"])
    overflow = jax.device_get(trace["overflow"])
    log = []
    for t in range(args.ticks):
        row = {"tick": t, "eval_loss": float(metric[t]),
               "train_key_sizes": [int(s) for s in sizes[t]],
               "overflow": int(overflow[t])}
        log.append(row)
        print(f"[train] tick={t:4d} eval={float(metric[t]):7.4f} "
              f"|S|(top-{Q})={sizes[t].tolist()}", flush=True)
    ov = int(jax.device_get(state.overflow).sum())
    print(f"[train] bank done: routed-overflow={ov} items "
          f"(per-key bcap={bcap})", flush=True)
    if telemetry is not None:
        telemetry.close()
    return log


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_12b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--scheme", default="rtbs",
                    choices=["rtbs", "sw", "brs", "btbs", "ttbs",
                             "drtbs", "dttbs"])
    ap.add_argument("--shards", type=int, default=8,
                    help="data-axis width for the distributed schemes")
    ap.add_argument("--num-keys", type=int, default=0,
                    help="multi-tenant mode: maintain one per-key "
                         "time-biased sample for this many entities "
                         "(repro.bank; rtbs/ttbs only, DESIGN.md Sec. 13)")
    ap.add_argument("--train-keys", type=int, default=8,
                    help="bank mode: retrain on / log the pooled sample of "
                         "this many most-popular keys")
    ap.add_argument("--bank-bcap", type=int, default=None,
                    help="bank mode: static per-key sub-batch capacity "
                         "(default: the whole tick batch, no routing drops)")
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--batch-per-tick", type=int, default=32)
    ap.add_argument("--reservoir", type=int, default=256)
    ap.add_argument("--lam", type=float, default=0.07)
    ap.add_argument("--decay", default="exp", choices=["exp", "poly"],
                    help="decay schedule: exp (rate --lam) or poly "
                         "(power-law, exponent --beta; DESIGN.md Sec. 12)")
    ap.add_argument("--beta", type=float, default=0.8,
                    help="polynomial-decay exponent (--decay poly)")
    ap.add_argument("--adaptive", action="store_true",
                    help="closed-loop decay: drive lambda from the "
                         "prequential loss (starts at --lam, clipped to "
                         "[--lam-min, --lam-max])")
    ap.add_argument("--lam-min", type=float, default=None)
    ap.add_argument("--lam-max", type=float, default=None)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--retrain-every", type=int, default=5)
    ap.add_argument("--superbatch", type=int, default=None,
                    help="manage-loop chunk size G (divisor of "
                         "--retrain-every; default: 8 on TPU, 1 elsewhere "
                         "-- DESIGN.md Sec. 11)")
    ap.add_argument("--retrain-steps", type=int, default=8)
    ap.add_argument("--train-batch", type=int, default=16)
    ap.add_argument("--drift", default="periodic", choices=["periodic", "single", "none"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--telemetry-dir", default=None,
                    help="write in-loop telemetry (one JSONL record per "
                         "tick + health-monitor warnings) under this "
                         "directory (repro.obs, DESIGN.md Sec. 14)")
    ap.add_argument("--telemetry-every", type=int, default=64,
                    help="telemetry drain period in ticks (fused loops "
                         "round it to whole superbatch chunks)")
    ap.add_argument("--telemetry-stdout", action="store_true",
                    help="echo telemetry records to stdout")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace (TensorBoard/"
                         "Perfetto-loadable) under this directory")
    ap.add_argument("--profile-ticks", type=int, default=8,
                    help="per-tick driver: ticks to bracket with the "
                         "profiler (fused loops capture the whole program)")
    args = ap.parse_args(argv)

    if args.scheme in DISTRIBUTED_SCHEMES:
        flag = "--xla_force_host_platform_device_count"
        if jax.device_count() < args.shards:
            if argv is None and flag not in os.environ.get("XLA_FLAGS", ""):
                # same pattern as examples/distributed_reservoir.py and the
                # per-pod production launcher: the devices must exist before
                # jax initializes, so re-exec with the flag set
                os.environ["XLA_FLAGS"] = (
                    os.environ.get("XLA_FLAGS", "") + f" {flag}={args.shards}"
                ).strip()
                os.execv(sys.executable, [sys.executable] + sys.argv)
            args.shards = jax.device_count()  # programmatic call: clamp
        # pad the tick batch to a multiple of the mesh BEFORE the sampler is
        # built: dttbs calibrates its acceptance rates p/q on the per-shard
        # arrival rate, and the SGD adapter's LM loss needs padding-free
        # shard segments (see run_sharded)
        b = -(-args.batch_per_tick // args.shards) * args.shards
        if b != args.batch_per_tick:
            print(f"[train] batch-per-tick {args.batch_per_tick} -> {b} "
                  f"(multiple of {args.shards} shards)")
            args.batch_per_tick = b

    cfg = (C.get_smoke_config(args.arch) if args.preset == "smoke"
           else C.get_config(args.arch))
    api = zoo.build(cfg)
    stream = TokenDriftStream(seed=args.seed, vocab=cfg.vocab_size,
                              seq_len=args.seq_len)

    # fixed schedule horizon: must NOT depend on --ticks, or an interrupted
    # run would train under a different LR curve than the run it resumes
    adapter = make_sgd_adapter(
        init_params=lambda: api.init_params(jax.random.key(args.seed)),
        train_step=make_train_step(
            api, AdamWConfig(lr=args.lr), microbatches=1,
            warmup=2, total_steps=4000,
        ),
        init_opt_state=adamw_init,
        loss=api.loss,
        batch_field="tokens",
        train_batch=args.train_batch,
        retrain_steps=args.retrain_steps,
        name=args.arch,
    )
    if args.num_keys:
        return run_bank(args, adapter, cfg)

    sched, controller = build_decay(args)
    sampler = build_sampler(args.scheme, n=args.reservoir, lam=args.lam,
                            batch_per_tick=args.batch_per_tick,
                            shards=args.shards, decay=sched)
    if args.scheme in DISTRIBUTED_SCHEMES:
        return run_sharded(args, adapter, stream, sampler, controller)

    fit = jax.jit(adapter.fit)
    eval_fn = jax.jit(adapter.evaluate)
    proto = jax.ShapeDtypeStruct((args.seq_len,), jnp.int32)
    st = sampler.init(proto)
    model_state = adapter.init()
    cstate = controller.init() if controller is not None else None
    start_tick = 0

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            like = (model_state, st, cstate, 0) if controller is not None \
                else (model_state, st, 0)
            tree = restore_checkpoint(args.ckpt_dir, last, like)
            if controller is not None:
                model_state, st, cstate, start_tick = tree
                cstate = jax.tree_util.tree_map(jnp.asarray, cstate)
            else:
                model_state, st, start_tick = tree
            model_state = jax.tree_util.tree_map(jnp.asarray, model_state)
            st = jax.tree_util.tree_map(jnp.asarray, st)
            start_tick = int(start_tick)
            print(f"[train] resumed from step {last} (tick {start_tick})")

    telemetry = build_telemetry(args)
    state_stats = obs_probe.make_state_stats(sampler)
    d_static = obs_probe.static_decay(sampler)
    if telemetry is not None:
        telemetry.open_run({"scheme": args.scheme, "ticks": args.ticks,
                            "superbatch": 1, "every": telemetry.every,
                            "backend": jax.default_backend(),
                            "jax": jax.__version__,
                            "state_bytes": obs_probe.tree_nbytes(st)})
    prof = contextlib.ExitStack()

    log = []
    for t in range(start_tick, args.ticks):
        if args.profile_dir and t == start_tick:
            prof.enter_context(profile_span(args.profile_dir))
        if args.profile_dir and t == start_tick + args.profile_ticks:
            prof.close()
        mode = 0 if args.drift == "none" else mode_schedule(args.drift, t)
        batch = jnp.asarray(stream.batch(t, args.batch_per_tick, mode))

        # prequential eval BEFORE the model sees this data
        eval_loss = float(eval_fn(model_state, batch, args.batch_per_tick))

        # sample update (the paper's technique); with --adaptive the
        # controller's current rate drives the step and the prequential loss
        # feeds back (adjustment gated on retrain ticks, as in the fused loop)
        key_t = jax.random.fold_in(jax.random.key(args.seed + 1), t)
        if controller is not None:
            d_t = controller.rate(cstate)
            st = sampler.step_decayed(key_t, st, batch,
                                      jnp.int32(args.batch_per_tick), d_t)
            cstate = controller.observe(
                cstate, jnp.float32(eval_loss),
                (t + 1) % args.retrain_every == 0)
        else:
            st = sampler.step(key_t, st, batch,
                              jnp.int32(args.batch_per_tick))

        # ONE realization per tick: the logged |S| is the sample fit trains on
        k_ex, k_fit = jax.random.split(
            jax.random.fold_in(jax.random.key(args.seed + 2), t)
        )
        view = sampler.extract(k_ex, st)
        size = int(view.size)

        # periodic retraining on the realized time-biased sample
        train_loss = float("nan")
        if (t + 1) % args.retrain_every == 0 and size >= args.train_batch:
            model_state = fit(k_fit, model_state, view)
            train_loss = float(
                eval_fn(model_state, batch, args.batch_per_tick)
            )

        # every scheme's state carries W_t (decayed weight for rtbs/ttbs/btbs,
        # item count for brs/sw); time-varying schedules wrap it
        raw = st.inner if isinstance(st, dk.DecayedState) else st
        total_w = float(raw.total_weight)
        row = {"tick": t, "mode": mode, "eval_loss": eval_loss,
               "train_loss": train_loss, "sample_size": size,
               "total_weight": total_w}
        extra = ""
        if controller is not None:
            row["lam"] = float(jnp.exp(cstate.loglam))
            extra = f" lam={row['lam']:6.4f}"
        log.append(row)
        if telemetry is not None:
            rec = {"kind": "tick", "t": t,
                   "bcount": args.batch_per_tick,
                   "metric": eval_loss, "size": size,
                   "retrain": (t + 1) % args.retrain_every == 0}
            rec.update({k: float(v) for k, v in state_stats(st).items()})
            if controller is not None:
                rec["decay"] = float(d_t)
                rec["lam"] = row["lam"]
            elif d_static is not None:
                rec["decay"] = d_static
            telemetry.emit(rec)
        print(f"[train] tick={t:4d} mode={mode} eval={eval_loss:7.4f} "
              f"train={train_loss:7.4f} |S|={size:5d} W={total_w:8.2f}"
              f"{extra}", flush=True)

        if ckpt and (t + 1) % args.ckpt_every == 0:
            snap = (model_state, st, cstate, t + 1) \
                if controller is not None else (model_state, st, t + 1)
            ckpt.save(t + 1, snap)
    prof.close()
    if ckpt:
        ckpt.wait()
    if telemetry is not None:
        telemetry.close()
    return log


if __name__ == "__main__":
    main()
