"""Online model management driver (the paper's loop, lifted to LMs):

  stream -> time-biased sample update -> (drift-triggered | periodic)
  retraining on the current sample -> prequential evaluation -> checkpoint.

The sampler is any scheme from the unified registry (``--scheme rtbs|sw|brs|
btbs|ttbs``, see :mod:`repro.core.api`); retraining runs through the
:mod:`repro.manage` SGD adapter, so the reservoir update and the whole
retrain inner loop are compiled programs. Runs any `--arch` (reduced
`--preset smoke` configs on CPU; full configs are for real pods). Fault
tolerance: `--resume` restarts bit-exactly from the newest checkpoint
(params, optimizer, reservoir, stream position).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm_12b \
      --preset smoke --ticks 30 --retrain-every 5 --scheme rtbs
"""
from __future__ import annotations

import argparse
import math

import jax
import jax.numpy as jnp

from repro import config as C
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core.api import available_schemes, make_sampler
from repro.data.streams import TokenDriftStream, mode_schedule
from repro.manage import make_sgd_adapter
from repro.models import zoo
from repro.optim import AdamWConfig, adamw_init
from repro.train.steps import make_train_step


def build_sampler(scheme: str, *, n: int, lam: float, batch_per_tick: int):
    """Map the driver's knobs onto each scheme's hyperparameters."""
    if scheme == "rtbs":
        return make_sampler("rtbs", n=n, lam=lam)
    if scheme in ("sw", "brs"):
        return make_sampler(scheme, n=n)
    if scheme == "btbs":
        # B-TBS has NO size control (paper Alg. 4): steady-state E|S| is
        # b/(1-e^-lam), not --reservoir. Provision 3x that so the capacity
        # bound never silently distorts the time bias.
        steady = batch_per_tick / max(1.0 - math.exp(-lam), 1e-6)
        return make_sampler("btbs", lam=lam, cap=max(n, int(3 * steady) + 1))
    if scheme == "ttbs":
        return make_sampler("ttbs", n=n, lam=lam, batch_size=batch_per_tick)
    raise ValueError(f"unsupported scheme {scheme!r}; see {available_schemes()}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_12b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--scheme", default="rtbs",
                    choices=["rtbs", "sw", "brs", "btbs", "ttbs"])
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--batch-per-tick", type=int, default=32)
    ap.add_argument("--reservoir", type=int, default=256)
    ap.add_argument("--lam", type=float, default=0.07)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--retrain-every", type=int, default=5)
    ap.add_argument("--retrain-steps", type=int, default=8)
    ap.add_argument("--train-batch", type=int, default=16)
    ap.add_argument("--drift", default="periodic", choices=["periodic", "single", "none"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)

    cfg = (C.get_smoke_config(args.arch) if args.preset == "smoke"
           else C.get_config(args.arch))
    api = zoo.build(cfg)
    stream = TokenDriftStream(seed=args.seed, vocab=cfg.vocab_size,
                              seq_len=args.seq_len)

    # fixed schedule horizon: must NOT depend on --ticks, or an interrupted
    # run would train under a different LR curve than the run it resumes
    adapter = make_sgd_adapter(
        init_params=lambda: api.init_params(jax.random.key(args.seed)),
        train_step=make_train_step(
            api, AdamWConfig(lr=args.lr), microbatches=1,
            warmup=2, total_steps=4000,
        ),
        init_opt_state=adamw_init,
        loss=api.loss,
        batch_field="tokens",
        train_batch=args.train_batch,
        retrain_steps=args.retrain_steps,
        name=args.arch,
    )
    fit = jax.jit(adapter.fit)
    eval_fn = jax.jit(adapter.evaluate)

    sampler = build_sampler(args.scheme, n=args.reservoir, lam=args.lam,
                            batch_per_tick=args.batch_per_tick)
    proto = jax.ShapeDtypeStruct((args.seq_len,), jnp.int32)
    st = sampler.init(proto)
    model_state = adapter.init()
    start_tick = 0

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            tree = restore_checkpoint(
                args.ckpt_dir, last, (model_state, st, 0)
            )
            model_state, st, start_tick = tree
            model_state = jax.tree_util.tree_map(jnp.asarray, model_state)
            st = jax.tree_util.tree_map(jnp.asarray, st)
            start_tick = int(start_tick)
            print(f"[train] resumed from step {last} (tick {start_tick})")

    log = []
    for t in range(start_tick, args.ticks):
        mode = 0 if args.drift == "none" else mode_schedule(args.drift, t)
        batch = jnp.asarray(stream.batch(t, args.batch_per_tick, mode))

        # prequential eval BEFORE the model sees this data
        eval_loss = float(eval_fn(model_state, batch, args.batch_per_tick))

        # sample update (the paper's technique)
        key_t = jax.random.fold_in(jax.random.key(args.seed + 1), t)
        st = sampler.step(key_t, st, batch, jnp.int32(args.batch_per_tick))

        # ONE realization per tick: the logged |S| is the sample fit trains on
        k_ex, k_fit = jax.random.split(
            jax.random.fold_in(jax.random.key(args.seed + 2), t)
        )
        view = sampler.extract(k_ex, st)
        size = int(view.size)

        # periodic retraining on the realized time-biased sample
        train_loss = float("nan")
        if (t + 1) % args.retrain_every == 0 and size >= args.train_batch:
            model_state = fit(k_fit, model_state, view)
            train_loss = float(
                eval_fn(model_state, batch, args.batch_per_tick)
            )

        # every scheme's state carries W_t (decayed weight for rtbs/ttbs/btbs,
        # item count for brs/sw)
        total_w = float(st.total_weight)
        log.append({"tick": t, "mode": mode, "eval_loss": eval_loss,
                    "train_loss": train_loss, "sample_size": size,
                    "total_weight": total_w})
        print(f"[train] tick={t:4d} mode={mode} eval={eval_loss:7.4f} "
              f"train={train_loss:7.4f} |S|={size:5d} W={total_w:8.2f}",
              flush=True)

        if ckpt and (t + 1) % args.ckpt_every == 0:
            ckpt.save(t + 1, (model_state, st, t + 1))
    if ckpt:
        ckpt.wait()
    return log


if __name__ == "__main__":
    main()
