"""Online model management driver (the paper's loop, lifted to LMs):

  stream -> R-TBS reservoir update -> (drift-triggered | periodic) retraining
  on the current time-biased sample -> prequential evaluation -> checkpoint.

Runs any `--arch` (reduced `--preset smoke` configs on CPU; full configs are
for real pods). Fault tolerance: `--resume` restarts bit-exactly from the
newest checkpoint (params, optimizer, reservoir, stream position).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm_12b \
      --preset smoke --ticks 30 --retrain-every 5
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import config as C
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core import latent as lt
from repro.core import rtbs
from repro.data.streams import TokenDriftStream, mode_schedule
from repro.models import zoo
from repro.optim import AdamWConfig, adamw_init
from repro.train.steps import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_12b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--batch-per-tick", type=int, default=32)
    ap.add_argument("--reservoir", type=int, default=256)
    ap.add_argument("--lam", type=float, default=0.07)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--retrain-every", type=int, default=5)
    ap.add_argument("--retrain-steps", type=int, default=8)
    ap.add_argument("--train-batch", type=int, default=16)
    ap.add_argument("--drift", default="periodic", choices=["periodic", "single", "none"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)

    cfg = (C.get_smoke_config(args.arch) if args.preset == "smoke"
           else C.get_config(args.arch))
    api = zoo.build(cfg)
    stream = TokenDriftStream(seed=args.seed, vocab=cfg.vocab_size,
                              seq_len=args.seq_len)

    params = api.init_params(jax.random.key(args.seed))
    opt_state = adamw_init(params)
    # fixed schedule horizon: must NOT depend on --ticks, or an interrupted
    # run would train under a different LR curve than the run it resumes
    train_step = jax.jit(
        make_train_step(
            api, AdamWConfig(lr=args.lr), microbatches=1,
            warmup=2, total_steps=4000,
        )
    )
    loss_fn = jax.jit(api.loss)

    # reservoir of token sequences
    proto = jax.ShapeDtypeStruct((args.seq_len,), jnp.int32)
    st = rtbs.init(proto, args.reservoir)
    start_tick = 0

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            tree = restore_checkpoint(
                args.ckpt_dir, last, (params, opt_state, st, 0)
            )
            params, opt_state, st, start_tick = tree
            params = jax.tree_util.tree_map(jnp.asarray, params)
            opt_state = jax.tree_util.tree_map(jnp.asarray, opt_state)
            st = jax.tree_util.tree_map(jnp.asarray, st)
            start_tick = int(start_tick)
            print(f"[train] resumed from step {last} (tick {start_tick})")

    log = []
    for t in range(start_tick, args.ticks):
        mode = 0 if args.drift == "none" else mode_schedule(args.drift, t)
        batch_np = stream.batch(t, args.batch_per_tick, mode)
        batch = jnp.asarray(batch_np)

        # prequential eval BEFORE the model sees this data
        eval_loss = float(loss_fn(params, {"tokens": batch}))

        # reservoir update (the paper's technique)
        key_t = jax.random.fold_in(jax.random.key(args.seed + 1), t)
        st = rtbs.step(key_t, st, batch, jnp.int32(args.batch_per_tick),
                       n=args.reservoir, lam=args.lam)

        # periodic retraining on the realized time-biased sample
        train_loss = float("nan")
        if (t + 1) % args.retrain_every == 0:
            mask, size = rtbs.realize(
                jax.random.fold_in(jax.random.key(args.seed + 2), t), st
            )
            items = st.lat.items
            size_i = int(size)
            if size_i >= args.train_batch:
                idx_pool = np.where(np.asarray(mask))[0]
                rs = np.random.RandomState(t)
                for it in range(args.retrain_steps):
                    sel = rs.choice(idx_pool, size=args.train_batch, replace=True)
                    mb = jnp.asarray(np.asarray(items)[sel])
                    params, opt_state, metrics = train_step(
                        params, opt_state, {"tokens": mb}
                    )
                train_loss = float(metrics["loss"])

        log.append({"tick": t, "mode": mode, "eval_loss": eval_loss,
                    "train_loss": train_loss,
                    "sample_weight": float(st.lat.weight),
                    "total_weight": float(st.total_weight)})
        print(f"[train] tick={t:4d} mode={mode} eval={eval_loss:7.4f} "
              f"train={train_loss:7.4f} C={float(st.lat.weight):8.2f}",
              flush=True)

        if ckpt and (t + 1) % args.ckpt_every == 0:
            ckpt.save(t + 1, (params, opt_state, st, t + 1))
    if ckpt:
        ckpt.wait()
    return log


if __name__ == "__main__":
    main()
