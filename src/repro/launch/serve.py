"""Batched serving driver: prefill a batch of prompts, decode greedily.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2_370m \
      --preset smoke --prompts 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import config as C
from repro.models import zoo
from repro.train.steps import make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_370m")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (C.get_smoke_config(args.arch) if args.preset == "smoke"
           else C.get_config(args.arch))
    api = zoo.build(cfg)
    params = api.init_params(jax.random.key(args.seed))

    batch = zoo.make_demo_batch(
        cfg, jax.random.key(args.seed + 1), args.prompts, args.prompt_len
    )
    max_len = args.prompt_len + args.gen + 1
    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, b: api.prefill(p, b, max_len)
    )(params, batch)
    tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    print(f"[serve] prefill: {time.time()-t0:.2f}s")

    # NOTE: prefill caches were built at prompt length; decode appends.
    decode = jax.jit(make_decode_step(api))
    outs = [tok]
    t0 = time.time()
    for _ in range(args.gen):
        tok, caches = decode(params, caches, tok)
        outs.append(tok)
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    dt = time.time() - t0
    print(f"[serve] decoded {args.gen} tokens x {args.prompts} seqs "
          f"in {dt:.2f}s ({args.gen*args.prompts/dt:.1f} tok/s)")
    print("[serve] first sequence:", gen[0].tolist())
    return gen


if __name__ == "__main__":
    main()
