"""Batched serving driver: prefill a batch of prompts, decode greedily.

With ``--telemetry-dir`` (or a :class:`repro.obs.Telemetry` handle passed
programmatically) the driver emits one ``kind="query"`` record per served
prompt -- prompt/generated lengths, prefill and decode wall time, cumulative
tokens served -- through the same sinks the manage loops drain into
(DESIGN.md Sec. 14), so a serving fleet and a training loop can share one
telemetry stream.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2_370m \
      --preset smoke --prompts 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import config as C
from repro.models import zoo
from repro.obs import make_telemetry
from repro.obs.profile import annotation
from repro.train.steps import make_decode_step


def main(argv=None, telemetry=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_370m")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry-dir", default=None,
                    help="write per-query serving telemetry (JSONL) under "
                         "this directory (repro.obs)")
    ap.add_argument("--telemetry-stdout", action="store_true",
                    help="echo telemetry records to stdout")
    args = ap.parse_args(argv)
    own_telemetry = False
    if telemetry is None and (args.telemetry_dir or args.telemetry_stdout):
        telemetry = make_telemetry(args.telemetry_dir,
                                   stdout=args.telemetry_stdout, monitors=())
        own_telemetry = True

    cfg = (C.get_smoke_config(args.arch) if args.preset == "smoke"
           else C.get_config(args.arch))
    api = zoo.build(cfg)
    params = api.init_params(jax.random.key(args.seed))
    if telemetry is not None:
        telemetry.open_run({"mode": "serve", "arch": args.arch,
                            "prompts": args.prompts,
                            "prompt_len": args.prompt_len, "gen": args.gen,
                            "backend": jax.default_backend(),
                            "jax": jax.__version__})

    batch = zoo.make_demo_batch(
        cfg, jax.random.key(args.seed + 1), args.prompts, args.prompt_len
    )
    max_len = args.prompt_len + args.gen + 1
    t0 = time.time()
    with annotation("serve.prefill"):
        logits, caches = jax.jit(
            lambda p, b: api.prefill(p, b, max_len)
        )(params, batch)
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1)
        tok = tok.astype(jnp.int32)
        tok.block_until_ready()
    prefill_s = time.time() - t0
    print(f"[serve] prefill: {prefill_s:.2f}s")

    # NOTE: prefill caches were built at prompt length; decode appends.
    decode = jax.jit(make_decode_step(api))
    outs = [tok]
    t0 = time.time()
    with annotation("serve.decode"):
        for _ in range(args.gen):
            tok, caches = decode(params, caches, tok)
            outs.append(tok)
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    dt = time.time() - t0
    print(f"[serve] decoded {args.gen} tokens x {args.prompts} seqs "
          f"in {dt:.2f}s ({args.gen*args.prompts/dt:.1f} tok/s)")
    print("[serve] first sequence:", gen[0].tolist())
    if telemetry is not None:
        served = 0
        for q in range(args.prompts):
            served += int(gen.shape[1])
            telemetry.emit({
                "kind": "query", "query": q,
                "prompt_len": args.prompt_len,
                "gen_tokens": int(gen.shape[1]),
                "tokens_served": served,  # cumulative across the batch
                "prefill_s": prefill_s / args.prompts,
                "decode_s": dt / args.prompts,
                "tok_per_s": args.gen * args.prompts / max(dt, 1e-9),
            })
        telemetry.flush()
        if own_telemetry:
            telemetry.close()
    return gen


if __name__ == "__main__":
    main()
