import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the production 512-chip mesh out of
# host placeholder devices; smoke tests and benchmarks see the default 1.

import argparse            # noqa: E402
import dataclasses         # noqa: E402
import json                # noqa: E402
import pathlib             # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import config as C      # noqa: E402
from repro import sharding as SH   # noqa: E402
from repro.launch import hlo_analysis, hw  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import zoo       # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step  # noqa: E402

# Gradient-accumulation factors for train_4k (activation-memory knob; see
# EXPERIMENTS.md §Dry-run memory table). Keys absent -> 1.
MICROBATCHES = {
    "mistral_large_123b": 16,
    "mixtral_8x22b": 8,
    "command_r_35b": 8,
    "granite_20b": 8,
    "stablelm_12b": 16,
    "zamba2_2p7b": 4,
    "qwen2_vl_2b": 4,
    "granite_moe_3b": 4,
    "whisper_large_v3": 4,
    "mamba2_370m": 2,
}

# paper-faithful baseline knobs applied to every cell (hillclimb variants
# override these via --override / the §Perf scripts)
BASE_OVERRIDES = {"attn_chunk": 2048}


def _shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def cost_depths(cfg):
    """(L1-overrides, L2-overrides, n_units_full, n_units(L1), n_units(L2)) for
    the two unrolled cost compiles. Layer stacks are homogeneous, so the
    difference of two depths gives the exact per-unit cost (the embed/logits
    ends cancel); hybrid uses whole groups and enc-dec uses (enc,dec) pairs."""
    if cfg.family == "hybrid":
        k = cfg.attn_every
        g_full = cfg.num_layers // k
        return ({"num_layers": k}, {"num_layers": 2 * k}, g_full, 1, 2)
    if cfg.is_encoder_decoder:
        return (
            {"num_layers": 2, "encoder_layers": 2},
            {"num_layers": 4, "encoder_layers": 4},
            cfg.num_layers, 2, 4,
        )
    l1 = min(2, cfg.num_layers)
    l2 = min(6, cfg.num_layers)
    if l1 == l2:
        l1 = 1
    return ({"num_layers": l1}, {"num_layers": l2}, cfg.num_layers, l1, l2)


def build_cell(arch: str, shape_name: str, mesh, *, overrides=None,
               variant: str = "cost"):
    """Construct (lower-ready fn, arg SDS tree, in/out shardings) for a cell.

    Cost-fidelity scheme (EXPERIMENTS.md §Dry-run methodology): XLA's
    HloCostAnalysis counts while-loop bodies ONCE, so per cell we compile
      * 'mem' variant: full depth, scanned layers, true grad-accumulation ->
        memory_analysis (the realistic peak footprint), and
      * two 'cost' variants: UNROLLED layer stacks at two small depths, one
        microbatch -> exact per-layer FLOPs/bytes/collectives by difference,
        extrapolated linearly in depth (layers are homogeneous).
    Inner chunk scans (online-softmax attention, SSD) stay scanned and get
    documented analytic corrections."""
    cfg = C.get_config(arch)
    shape = C.SHAPES[shape_name]
    mi = SH.mesh_info(mesh)
    dp = 1
    for a in mi.batch_axes:
        dp *= mi.axis_sizes[a]

    over0 = dict(overrides or {})
    mb_override = over0.pop("microbatches", None)
    mb = mb_override or (MICROBATCHES.get(arch, 1) if shape.kind == "train" else 1)
    fold_mb = variant != "mem"
    if shape.kind == "train" and mb > 1 and fold_mb:
        shape = dataclasses.replace(shape, global_batch=shape.global_batch // mb)

    over = over0
    # decode mem-variant also unrolls: scanned cache carries defeat XLA's
    # donation-based in-place cache updates (spurious temp copies)
    over.setdefault(
        "scan_layers", variant == "mem" and shape.kind != "decode"
    )
    if shape.kind == "decode":
        KV = cfg.num_kv_heads
        if KV and SH.head_mode(cfg, mi.tp) == "heads_qonly" and mi.tp % KV == 0:
            over.setdefault("kv_replication", mi.tp // KV)
    if cfg.num_experts:
        over.setdefault("moe_groups", min(dp, shape.global_batch))
    cfg = dataclasses.replace(cfg, **over)
    api = zoo.build(cfg)

    params_sds = jax.eval_shape(api.init_params, jax.random.key(0))
    pspecs = SH.param_pspecs(cfg, params_sds, mi)
    psh = _shardings(mesh, pspecs)
    batch_sds = zoo.input_specs(cfg, shape)
    bsh = _shardings(mesh, SH.batch_pspecs(cfg, batch_sds, mi))

    if shape.kind == "train":
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        osh = {"m": psh, "v": psh, "count": NamedSharding(mesh, P())}
        step = make_train_step(
            api, AdamWConfig(), microbatches=(mb if not fold_mb else 1)
        )
        fn = jax.jit(
            step,
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1),
        )
        args = (params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        step = make_prefill_step(api, max_len=shape.seq_len)
        fn = jax.jit(step, in_shardings=(psh, bsh), out_shardings=None)
        args = (params_sds, batch_sds)
    else:  # decode
        caches_sds = jax.eval_shape(
            lambda: api.init_decode_state(
                shape.global_batch,
                max_len=shape.seq_len + 1,
                prefill_len=shape.seq_len,
            )
        )
        csh = _shardings(mesh, SH.cache_pspecs(cfg, caches_sds, mi))
        tok_sds = {"tok": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}
        tsh = _shardings(mesh, SH.batch_pspecs(cfg, tok_sds, mi))["tok"]
        step = make_decode_step(api)
        fn = jax.jit(
            step,
            in_shardings=(psh, csh, tsh),
            out_shardings=(tsh, csh),
            donate_argnums=(1,),
        )
        args = (params_sds, caches_sds, tok_sds["tok"])
    return cfg, shape, fn, args, mb


def model_flops(cfg, shape, mb) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (N = active params for MoE),
    2*N*D for forward-only (prefill/decode). shape is PRE-microbatch-fold."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def inner_scan_correction(cfg, shape, mb) -> float:
    """Analytic TOTAL-FLOPs correction for loop bodies HloCostAnalysis counts
    once (documented in EXPERIMENTS.md §Dry-run):

      * online-softmax chunked attention (S >= attn_chunk): missing
        4*B*hd*H*(S*T - bq*bk) per attention call
      * SSD chunk scan: missing (nc-1) x per-chunk body per Mamba2 layer

    Training multiplies by 4 (fwd + remat recompute + ~2x bwd); forward-only
    by 1. Corrections use the same unmasked-causal convention as the HLO."""
    mult = 4.0 if shape.kind == "train" else 1.0
    B_eff = shape.global_batch // (mb if shape.kind == "train" else 1)
    S = shape.seq_len if shape.kind != "decode" else 1
    total = 0.0
    # chunked attention
    if shape.kind in ("train", "prefill") and cfg.num_heads:
        bq = bk = cfg.attn_chunk
        if cfg.attn_chunk and S >= cfg.attn_chunk:
            T_len = S
            H, hd = cfg.num_heads, cfg.resolved_head_dim
            per_call = 4.0 * B_eff * hd * H * (S * T_len - bq * bk)
            if cfg.family == "hybrid":
                ncalls = cfg.num_layers // cfg.attn_every
            elif cfg.family == "audio":
                ncalls = cfg.num_layers  # decoder self-attn (encoder is 1500)
            else:
                ncalls = cfg.num_layers
            total += per_call * ncalls
    # SSD chunks
    if cfg.ssm_state and shape.kind in ("train", "prefill"):
        Q = min(cfg.ssm_chunk, S)
        nc = max(S // Q, 1)
        if nc > 1:
            G_, N_ = cfg.ssm_groups, cfg.ssm_state
            H_, P_ = cfg.ssm_heads, cfg.ssm_head_dim
            body = B_eff * (
                2.0 * Q * Q * G_ * N_       # C.B scores
                + 2.0 * Q * Q * H_ * P_     # y_intra
                + 2.0 * Q * H_ * N_ * P_    # y_inter
                + 2.0 * Q * H_ * N_ * P_    # state update
            )
            total += (nc - 1) * body * cfg.num_layers
    return total * mult * (mb if shape.kind == "train" else 1)


def _compile_once(arch, shape_name, mesh, pod_size, overrides, variant):
    t0 = time.time()
    cfg, shape, fn, args, mb = build_cell(
        arch, shape_name, mesh, overrides=overrides, variant=variant
    )
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = hlo_analysis.collective_stats(hlo, pod_size=pod_size)
    return {
        "cfg": cfg,
        "mb": mb,
        "t_compile": t_compile,
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": colls["bytes"],
        "coll_counts": colls["counts"],
        "mem": mem,
        "hlo_len": len(hlo),
    }


def _extrapolate(c1, c2, u1, u2, u_full):
    """Linear-in-depth extrapolation of per-device costs from two unrolled
    compiles (exact for homogeneous layer stacks: the ends cancel)."""
    def ex(a, b):
        per = (b - a) / max(u2 - u1, 1)
        return max(a + per * (u_full - u1), 0.0)

    coll_keys = set(c1["coll"]) | set(c2["coll"])
    return {
        "flops": ex(c1["flops"], c2["flops"]),
        "bytes": ex(c1["bytes"], c2["bytes"]),
        "coll": {
            k: ex(c1["coll"].get(k, 0.0), c2["coll"].get(k, 0.0))
            for k in coll_keys
        },
    }


def analytic_hbm_bytes(cfg, shape, mb, mi) -> float:
    """Per-device-per-step HBM traffic model for the TPU target (documented in
    EXPERIMENTS.md §Roofline). XLA-CPU's 'bytes accessed' is fusion-naive
    (~100x TPU reality), so the memory roofline term uses this analytic model;
    the raw XLA number is kept in the artifact for reference.

    Terms: FSDP-gathered weight traffic, optimizer pass, per-layer activation
    streams, dense-attention score streams (only when the dense path is used;
    chunked/flash keeps scores in VMEM), logits, KV/SSM cache traffic."""
    dp = 1
    for a in mi.batch_axes:
        dp *= mi.axis_sizes[a]
    tp = mi.tp
    P = cfg.param_count()
    B, S = shape.global_batch, shape.seq_len
    Vp, D = cfg.padded_vocab, cfg.d_model
    L = cfg.num_layers

    if shape.kind == "decode":
        # weight-read bound: every active weight read once per token step
        w = cfg.active_param_count() / (dp * tp) * 2
        cache = 0.0
        if cfg.num_heads:
            T = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
            KVh = cfg.num_kv_heads * cfg.kv_replication
            kv_shard = tp if (KVh % tp == 0) else (
                tp if cfg.resolved_head_dim % tp == 0 else 1)
            ncaches = (L // cfg.attn_every) if cfg.family == "hybrid" else L
            cache += (B / dp) * T * KVh * cfg.resolved_head_dim * 2 * 2 \
                * ncaches / kv_shard
            if cfg.is_encoder_decoder:
                cache += (B / dp) * cfg.encoder_seq * KVh \
                    * cfg.resolved_head_dim * 2 * 2 * L / kv_shard
        if cfg.ssm_state:
            h_shard = tp if cfg.ssm_heads % tp == 0 else 1
            cache += (B / dp) * cfg.ssm_heads * cfg.ssm_state \
                * cfg.ssm_head_dim * 4 * L / h_shard
        logits = (B / dp) * Vp / tp * 4
        return w + cache + logits

    passes = 3.0 if shape.kind == "train" else 1.0
    B_micro = B // (mb if shape.kind == "train" else 1)
    tok_loc = B_micro * S / dp
    # FSDP-gathered weights: one gathered copy per pass per microbatch
    weights = (P / tp) * 2 * (passes + 1)
    # activations: ~alpha streamed [tok, D] tensors per layer per pass
    alpha = 16 if cfg.num_experts else 10
    acts = alpha * tok_loc * D * 2 * passes * L
    # dense-attention scores hit HBM only when the dense path is used
    scores = 0.0
    if cfg.num_heads and (not cfg.attn_chunk or S < cfg.attn_chunk):
        H_loc = cfg.num_heads / (tp if cfg.num_heads % tp == 0 else 1)
        ncalls = (L // cfg.attn_every) if cfg.family == "hybrid" else L
        scores = 2 * (B_micro / dp) * H_loc * S * S * 4 * passes * ncalls
    logits = tok_loc * (Vp / tp) * 4 * passes
    per_micro = acts + scores + logits + weights
    total = per_micro * (mb if shape.kind == "train" else 1)
    if shape.kind == "train":
        total += P / (dp * tp) * 4 * 6  # optimizer read/write p,m,v
    return total


def run_cell(arch, shape_name, mesh_name, outdir, *, overrides=None, tag=""):
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    nchips = mesh.devices.size
    pod_size = 256 if multi else 1 << 30
    shape_full = C.SHAPES[shape_name]

    overrides = {**BASE_OVERRIDES, **(overrides or {})}
    # (1) memory-fidelity compile: full depth, scanned, true grad accumulation.
    # This is the REAL production program -- for the multi-pod mesh this single
    # compile is the deliverable (proves the pod axis shards); the roofline
    # table is single-pod only (assignment), so cost extrapolation runs there.
    memrec = _compile_once(arch, shape_name, mesh, pod_size, overrides, "mem")
    mem = memrec["mem"]
    mb = memrec["mb"]
    cfg_full = memrec["cfg"]
    o1, o2, u_full, u1, u2 = cost_depths(cfg_full)
    base = dict(overrides or {})
    if mesh_name == "multi":
        c1 = c2 = memrec
        u1 = u2 = u_full  # no extrapolation: report the scanned program's stats
    else:
        # (2)+(3) cost-fidelity compiles: unrolled at two depths, extrapolate
        c1 = _compile_once(arch, shape_name, mesh, pod_size, {**base, **o1}, "cost")
        c2 = _compile_once(arch, shape_name, mesh, pod_size, {**base, **o2}, "cost")
    ext = _extrapolate(c1, c2, u1, u2, u_full)

    # a full step is mb identical microbatches (+ optimizer, already counted)
    flops_dev = ext["flops"] * mb
    bytes_xla = ext["bytes"] * mb
    mi = SH.mesh_info(mesh)
    bytes_dev = analytic_hbm_bytes(cfg_full, shape_full, mb, mi)
    coll_bytes = {k: v * mb for k, v in ext["coll"].items()}
    corr_total = inner_scan_correction(cfg_full, shape_full, mb)
    flops_dev_corr = flops_dev + corr_total / nchips
    mf = model_flops(cfg_full, shape_full, mb)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "tag": tag,
        "chips": int(nchips),
        "microbatches": mb,
        "params": cfg_full.param_count(),
        "active_params": cfg_full.active_param_count(),
        "compile_s": {
            "mem": round(memrec["t_compile"], 2),
            "cost_l1": round(c1["t_compile"], 2),
            "cost_l2": round(c2["t_compile"], 2),
        },
        "cost_extrapolation": {"u1": u1, "u2": u2, "u_full": u_full},
        "flops_per_device_raw": flops_dev,
        "flops_per_device": flops_dev_corr,
        "inner_scan_correction_total": corr_total,
        "hbm_bytes_per_device": bytes_dev,
        "hbm_bytes_xla_raw": bytes_xla,
        "collectives": {"bytes": coll_bytes, "counts": c2["coll_counts"]},
        "model_flops_total": mf,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "hlo_bytes": memrec["hlo_len"],
    }
    # roofline terms (seconds) -- single-pod convention per the assignment
    rec["roofline_valid"] = mesh_name == "single"
    rec["roofline"] = {
        "t_compute": flops_dev_corr / hw.PEAK_FLOPS_BF16,
        "t_memory": bytes_dev / hw.HBM_BW,
        "t_collective": coll_bytes.get("total", 0.0) / hw.ICI_BW,
        "t_dcn": coll_bytes.get("dcn", 0.0) / hw.DCN_BW,
        "useful_flops_ratio": mf / max(flops_dev_corr * nchips, 1.0),
    }
    dom = max(
        ("t_compute", "t_memory", "t_collective"),
        key=lambda k: rec["roofline"][k],
    )
    rec["roofline"]["dominant"] = dom

    out = pathlib.Path(outdir)
    out.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_name}{('__' + tag) if tag else ''}.json"
    (out / name).write_text(json.dumps(rec, indent=1))

    fits = rec["memory"]["peak_est_bytes"] <= hw.HBM_PER_CHIP
    print(
        f"[dryrun] {arch:>20s} {shape_name:>11s} {mesh_name:>6s} "
        f"compile=({memrec['t_compile']:.0f}+{c1['t_compile']:.0f}"
        f"+{c2['t_compile']:.0f})s flops/dev={flops_dev_corr:.3e} "
        f"mem={rec['memory']['peak_est_bytes']/2**30:6.2f}GiB "
        f"coll={coll_bytes.get('total',0)/2**20:9.2f}MiB "
        f"dom={dom[2:]} fits={fits} "
        f"useful={rec['roofline']['useful_flops_ratio']:.2f}",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--tag", default="", help="artifact tag (hillclimb variants)")
    ap.add_argument(
        "--set", action="append", default=[],
        help="ModelConfig override key=val (e.g. --set cast_params_once=true)",
    )
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                try:
                    overrides[k] = float(v)
                except ValueError:
                    overrides[k] = v

    cells = list(C.cells(include_skipped=True))
    if args.list:
        for a, s, skip in cells:
            print(f"{a:>20s} {s:>11s} {'SKIP: ' + skip if skip else 'run'}")
        return

    todo = []
    for a, s, skip in cells:
        if args.arch and a != C.ALIASES.get(args.arch, args.arch):
            continue
        if args.shape and s != args.shape:
            continue
        if not args.all and not args.arch and not args.shape:
            continue
        todo.append((a, s, skip))

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for a, s, skip in todo:
        if skip:
            print(f"[dryrun] {a:>20s} {s:>11s}  SKIPPED: {skip}", flush=True)
            rec = {"arch": a, "shape": s, "skipped": skip}
            out = pathlib.Path(args.out)
            out.mkdir(parents=True, exist_ok=True)
            (out / f"{a}__{s}__skip.json").write_text(json.dumps(rec))
            continue
        for m in meshes:
            try:
                run_cell(a, s, m, args.out, overrides=overrides, tag=args.tag)
            except Exception as e:  # noqa: BLE001
                failures.append((a, s, m, repr(e)))
                print(f"[dryrun] FAIL {a} {s} {m}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")
    print("[dryrun] all requested cells compiled OK", flush=True)


if __name__ == "__main__":
    main()
