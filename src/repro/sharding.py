"""Sharding rules: parameter / activation / cache PartitionSpecs for the
production meshes (DESIGN.md Sec. 6).

Scheme (MaxText-style logical axes, resolved per arch x mesh):
  * TP   = ``model`` axis: attention heads (or head_dim when heads don't
           divide), MLP/expert ff, vocab.
  * FSDP = ``data`` axis: the non-TP weight dim (d_model / expert dims), so
           optimizer state is fully sharded; params are replicated across the
           ``pod`` axis (only gradients cross DCN).
  * Batch = (``pod``, ``data``) for activations.

Head-sharding fallback chain per arch (q / kv decided together):
  heads-and-heads -> heads-and-replicated-kv (GQA with kv-head replication for
  caches) -> head_dim-and-head_dim -> replicated.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

FSDP, TP, POD = "data", "model", "pod"


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    axis_names: tuple
    axis_sizes: dict

    @property
    def tp(self) -> int:
        return self.axis_sizes.get(TP, 1)

    @property
    def fsdp(self) -> int:
        return self.axis_sizes.get(FSDP, 1)

    @property
    def batch_axes(self) -> tuple:
        return tuple(a for a in (POD, FSDP) if a in self.axis_names)


def mesh_info(mesh) -> MeshInfo:
    return MeshInfo(
        axis_names=tuple(mesh.axis_names),
        axis_sizes=dict(zip(mesh.axis_names, mesh.devices.shape)),
    )


def head_mode(cfg, tp: int) -> str:
    """'heads' | 'heads_qonly' | 'head_dim' | 'replicate'."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if H and H % tp == 0 and KV % tp == 0:
        return "heads"
    if H and H % tp == 0:
        return "heads_qonly"
    if hd and hd % tp == 0:
        return "head_dim"
    return "replicate"


def _div(n, size):
    return size > 1 and n % size == 0


def param_pspecs(cfg, params_tree, mi: MeshInfo) -> Any:
    """PartitionSpec pytree mirroring ``params_tree`` (arrays or SDS).
    cfg.fsdp_params=False switches to the inference layout: weights TP-only
    (replicated over data) so decode never re-gathers them per token."""
    tp = mi.tp
    fsdp = mi.fsdp if cfg.fsdp_params else 0
    mode = head_mode(cfg, tp)

    def qspec(shape):  # [L?, D, H, hd]
        lead = (None,) * (len(shape) - 3)
        d_ax = FSDP if _div(shape[-3], fsdp) else None
        if mode in ("heads", "heads_qonly"):
            return P(*lead, d_ax, TP, None)
        if mode == "head_dim":
            return P(*lead, d_ax, None, TP)
        return P(*lead, d_ax, None, None)

    def kvspec(shape):
        lead = (None,) * (len(shape) - 3)
        d_ax = FSDP if _div(shape[-3], fsdp) else None
        if mode == "heads":
            return P(*lead, d_ax, TP, None)
        if mode == "head_dim":
            return P(*lead, d_ax, None, TP)
        return P(*lead, d_ax, None, None)  # heads_qonly: kv replicated over TP

    def ospec(shape):  # [L?, H, hd, D]
        lead = (None,) * (len(shape) - 3)
        d_ax = FSDP if _div(shape[-1], fsdp) else None
        if mode in ("heads", "heads_qonly"):
            return P(*lead, TP, None, d_ax)
        if mode == "head_dim":
            return P(*lead, None, TP, d_ax)
        return P(*lead, None, None, d_ax)

    def rule(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = names[-1]
        shape = leaf.shape
        nd = len(shape)

        def dim(i, ax, size_req):
            return ax if _div(shape[i], size_req) else None

        if name == "embed":                       # [V, D]: Megatron-style
            # vocab-parallel -- lookup lowers to masked-local-gather + psum of
            # [B,S,D] (cheap); tied logits matmul is then local over V(tp).
            return P(dim(0, TP, tp), None)
        if name == "unembed":                     # [D, V]
            return P(None, dim(1, TP, tp))
        if name in ("wq",):
            return qspec(shape)
        if name in ("wk", "wv"):
            return kvspec(shape)
        if name == "wo" and "attn" in "".join(names):
            return ospec(shape)
        if name == "router":                      # [L?, D, E]
            return P(*(None,) * (nd - 2), dim(nd - 2, FSDP, fsdp), None)
        if name in ("wg", "wi"):
            if nd >= 3 and "moe" in names:        # [L?, E, D, F]
                return P(*(None,) * (nd - 2), dim(nd - 2, FSDP, fsdp), dim(nd - 1, TP, tp))
            return P(*(None,) * (nd - 2), dim(nd - 2, FSDP, fsdp), dim(nd - 1, TP, tp))
        if name == "wo":                          # mlp/moe [.., F, D]
            return P(*(None,) * (nd - 2), dim(nd - 2, TP, tp), dim(nd - 1, FSDP, fsdp))
        if name == "in_proj":                     # [L?, D, K]
            return P(*(None,) * (nd - 2), dim(nd - 2, FSDP, fsdp), dim(nd - 1, TP, tp))
        if name == "out_proj":                    # [L?, din, D]
            return P(*(None,) * (nd - 2), dim(nd - 2, TP, tp), dim(nd - 1, FSDP, fsdp))
        if name == "conv_w":                      # [L?, W, C]
            return P(*(None,) * (nd - 1), dim(nd - 1, TP, tp))
        if name == "conv_b":
            return P(*(None,) * (nd - 1), dim(nd - 1, TP, tp))
        return P(*(None,) * nd)                   # norms, biases, A_log, D, dt_bias

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def _ba(mi: MeshInfo, dim: int):
    """Batch axes if the dim divides the total DP width, else replicate
    (long_500k has global_batch=1: batch stays unsharded by design)."""
    width = 1
    for a in mi.batch_axes:
        width *= mi.axis_sizes[a]
    return mi.batch_axes if dim % width == 0 else None


def batch_pspecs(cfg, batch_tree, mi: MeshInfo) -> Any:
    """Inputs: batch dim over (pod, data); everything else replicated."""

    def rule(path, leaf):
        return P(_ba(mi, leaf.shape[0]), *(None,) * (len(leaf.shape) - 1))

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_pspecs(cfg, cache_tree, mi: MeshInfo) -> Any:
    """Decode caches: batch over (pod, data); kv-head or head_dim over model;
    SSM state heads over model. Leaves are identified by rank/shape."""
    tp = mi.tp
    mode = head_mode(cfg, tp)
    KV_eff = cfg.num_kv_heads * getattr(cfg, "kv_replication", 1)
    hd = cfg.resolved_head_dim

    def rule(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 0:
            return P()
        # find the batch dim: first dim not matching a leading stack axis is
        # handled generically -- stacked leading layer dims are small ints too,
        # so instead we type leaves by suffix:
        if nd >= 4 and (shape[-2:] == (KV_eff, hd) or shape[-1] == hd):
            # [..., B, T, KV_eff, hd]
            kv_ax = TP if (mode != "head_dim" and _div(shape[-2], tp)) else None
            hd_ax = TP if (mode == "head_dim" and _div(shape[-1], tp)) else None
            return P(*(None,) * (nd - 4), _ba(mi, shape[-4]), None, kv_ax, hd_ax)
        if nd >= 3 and shape[-1] == cfg.ssm_head_dim and shape[-2] == cfg.ssm_state:
            # SSM state [..., B, H, N, P]
            h_ax = TP if _div(shape[-3], tp) else None
            return P(*(None,) * (nd - 4), _ba(mi, shape[-4]), h_ax, None, None)
        if nd >= 2:  # conv cache [..., B, W-1, C] / generic
            c_ax = TP if _div(shape[-1], tp) else None
            if nd >= 3:
                return P(*(None,) * (nd - 3), _ba(mi, shape[-3]), None, c_ax)
            return P(*(None,) * (nd - 2), _ba(mi, shape[-2]), None)
        return P(None)  # lengths [L]

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def logits_pspec(mi: MeshInfo):
    return P(mi.batch_axes, None, TP)
