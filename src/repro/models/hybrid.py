"""Zamba2-style hybrid LM: Mamba2 backbone + ONE shared attention+MLP block
invoked every ``attn_every`` layers (weights shared across invocations, one KV
cache per invocation). [arXiv:2411.15242]

Structure: G groups, each = (attn_every Mamba2 layers) then the shared block.
Outer scan over groups (carrying hidden + group index), inner scan over the
group's Mamba2 layers. Deviation noted in DESIGN.md: the original concatenates
initial embeddings into the shared block input and adds per-invocation LoRA;
we apply the plain shared block."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as A
from . import layers as L
from . import ssm as S
from . import transformer as T


def _groups(cfg):
    assert cfg.num_layers % cfg.attn_every == 0
    return cfg.num_layers // cfg.attn_every


def init_params(cfg, key):
    ke, km, ka, ko = jax.random.split(key, 4)
    pd = L.param_dtype(cfg)
    params = {
        "embed": L.embed_init(ke, (cfg.padded_vocab, cfg.d_model), pd),
        "mamba": jax.vmap(
            lambda k: {"ln": L.norm_params(cfg, cfg.d_model),
                       "ssm": S.ssm_params(cfg, k)}
        )(jax.random.split(km, cfg.num_layers)),
        "shared": T.init_block_params(cfg, ka),
        "final_norm": L.norm_params(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(
            ko, (cfg.d_model, cfg.padded_vocab), pd, fan_in=cfg.d_model
        )
    return params


def _regroup(cfg, tree):
    """[L, ...] stacked leaves -> [G, attn_every, ...]."""
    G = _groups(cfg)
    return jax.tree_util.tree_map(
        lambda a: a.reshape((G, cfg.attn_every) + a.shape[1:]), tree
    )


def forward(cfg, params, batch):
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    x, positions = T._embed_inputs(cfg, params, batch)
    grouped = _regroup(cfg, params["mamba"])

    def mamba_layer(h, p):
        y, _ = S.apply_ssm(cfg, p["ssm"], L.apply_norm(cfg, p["ln"], h))
        return h + y, None

    def group(h, pg):
        fn = jax.checkpoint(mamba_layer) if cfg.remat else mamba_layer
        h, _ = T.scan_or_unroll(cfg, fn, h, pg)
        h = T._block_fwd(cfg, params["shared"], h, positions)
        return h, None

    # remat the whole group too: the shared attention block's intermediates
    # must not be stashed once per invocation
    gfn = jax.checkpoint(group) if cfg.remat else group
    x, _ = T.scan_or_unroll(cfg, gfn, x, grouped)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return T.logits_from_hidden(cfg, params, x)


def prefill(cfg, params, batch, max_len):
    """Prompt pass producing per-layer SSM caches + per-invocation KV caches."""
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    x, positions = T._embed_inputs(cfg, params, batch)
    grouped = _regroup(cfg, params["mamba"])

    def mamba_layer(h, p):
        y, cache = S.apply_ssm(cfg, p["ssm"], L.apply_norm(cfg, p["ln"], h))
        return h + y, cache

    def group(h, pg):
        h, ssm_c = T.scan_or_unroll(cfg, mamba_layer, h, pg)
        hn = L.apply_norm(cfg, params["shared"]["ln1"], h)
        y, kv_c = A.prefill_attention(cfg, params["shared"]["attn"], hn, positions, max_len)
        h = h + y
        h = h + T._ffn(cfg, params["shared"],
                       L.apply_norm(cfg, params["shared"]["ln2"], h))
        return h, (ssm_c, kv_c)

    x, (ssm_c, kv_c) = T.scan_or_unroll(cfg, group, x, grouped)
    x = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    return T.logits_from_hidden(cfg, params, x), {"ssm": ssm_c, "kv": kv_c}


# ---------------------------------------------------------------------------
# serving: per-layer SSM caches + per-invocation KV caches for the shared block
# ---------------------------------------------------------------------------
def init_decode_state(cfg, batch, max_len, prefill_len=0):
    dt = L.compute_dtype(cfg)
    G = _groups(cfg)
    ssm = S.init_ssm_cache(cfg, batch, dt)
    kv = A.init_cache(cfg, batch, max_len, dt, prefill_len)
    if cfg.scan_layers:
        ssm = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None, None], (G, cfg.attn_every) + a.shape
            ),
            ssm,
        )
        kv = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (G,) + a.shape)
            if getattr(a, "ndim", 0)
            else jnp.full((G,), a),
            kv,
        )
        return {"ssm": ssm, "kv": kv}
    copy = lambda t: jax.tree_util.tree_map(jnp.array, t)
    return {
        "ssm": [[copy(ssm) for _ in range(cfg.attn_every)] for _ in range(G)],
        "kv": [copy(kv) for _ in range(G)],
    }


def decode_step(cfg, params, caches, tokens):
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    dt = L.compute_dtype(cfg)
    x = params["embed"].astype(dt)[tokens]
    grouped = _regroup(cfg, params["mamba"])

    def mamba_layer(h, inp):
        p, cache = inp
        hn = L.apply_norm(cfg, p["ln"], h)
        y, cache = S.decode_ssm(cfg, p["ssm"], hn, cache)
        return h + y, cache

    def group(h, inp):
        pg, ssm_c, kv_c = inp
        if isinstance(ssm_c, list):
            h, ssm_c = T.unrolled_decode(mamba_layer, h, pg, ssm_c)
        else:
            h, ssm_c = jax.lax.scan(mamba_layer, h, (pg, ssm_c))
        hn = L.apply_norm(cfg, params["shared"]["ln1"], h)
        y, kv_c = A.decode_attention(cfg, params["shared"]["attn"], hn, kv_c)
        h = h + y
        h = h + T._ffn(cfg, params["shared"],
                       L.apply_norm(cfg, params["shared"]["ln2"], h))
        return h, (ssm_c, kv_c)

    if isinstance(caches["kv"], list):
        x = x
        ssm_out, kv_out = [], []
        for g, kv_c in enumerate(caches["kv"]):
            pg = jax.tree_util.tree_map(lambda a: a[g], grouped)
            x, (ssm_c, kv_c) = group(x, (pg, caches["ssm"][g], kv_c))
            ssm_out.append(ssm_c)
            kv_out.append(kv_c)
        x = L.apply_norm(cfg, params["final_norm"], x)
        return T.logits_from_hidden(cfg, params, x), {"ssm": ssm_out, "kv": kv_out}
    x, (ssm_c, kv_c) = jax.lax.scan(
        group, x, (grouped, caches["ssm"], caches["kv"])
    )
    x = L.apply_norm(cfg, params["final_norm"], x)
    return T.logits_from_hidden(cfg, params, x), {"ssm": ssm_c, "kv": kv_c}
