"""Unified model API over the zoo + loss functions + abstract input specs."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig

from . import encdec, hybrid, mamba_lm, transformer

VLM_PATCHES = 256  # stubbed vision prefix length (qwen2-vl dynamic-res stub)


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], Any]
    forward: Callable[[Any, Any], jax.Array]
    prefill: Callable[[Any, Any, int], Any]
    init_decode_state: Callable[..., Any]
    decode_step: Callable[[Any, Any, jax.Array], Any]

    def loss(self, params, batch):
        return loss_fn(self.cfg, self.forward, params, batch)


def build(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
    elif fam == "ssm":
        mod = mamba_lm
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "audio":
        mod = encdec
    else:
        raise ValueError(fam)
    return ModelAPI(
        cfg=cfg,
        init_params=lambda key: mod.init_params(cfg, key),
        forward=lambda params, batch: mod.forward(cfg, params, batch),
        prefill=lambda params, batch, max_len: mod.prefill(
            cfg, params, batch, max_len
        ),
        init_decode_state=lambda batch, max_len, prefill_len=0: mod.init_decode_state(
            cfg, batch, max_len, prefill_len
        ),
        decode_step=lambda params, caches, tokens: mod.decode_step(
            cfg, params, caches, tokens
        ),
    )


def precast(cfg, params):
    """§Perf: pre-cast params to the compute dtype ONCE before the layer stack
    (per-use .astype then no-ops), so FSDP all-gathers move bf16, not f32.
    Gradients still flow to the original (f32) leaves through the cast.

    The optimization barrier pins the cast BEFORE any resharding: without it
    XLA hoists the all-gather above the (elementwise) cast and the gathers
    still move f32 (measured -- EXPERIMENTS.md §Perf H1 iter 1)."""
    if not cfg.cast_params_once:
        return params
    dt = jnp.dtype(cfg.dtype)
    casted = jax.tree_util.tree_map(
        lambda p: p.astype(dt) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params,
    )
    return jax.lax.optimization_barrier(casted)


def loss_fn(cfg, forward, params, batch):
    """Next-token cross entropy in f32 (padded-vocab logits; labels < vocab)."""
    logits = forward(params, batch)
    tokens = batch["tokens"]
    # frontend prefix (vlm): loss only over the text segment
    offset = logits.shape[1] - tokens.shape[1]
    logits = logits[:, offset:]
    logits = logits[:, :-1].astype(jnp.float32)
    labels = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    nll = logz - gold
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# abstract input specs (the dry-run's ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for one global batch of this (arch, shape) cell.

    [vlm]/[audio] entries: the modality frontend is a STUB -- precomputed
    patch/frame embeddings are model inputs, per the assignment."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        return specs
    if cfg.family == "vlm":
        return {
            "tokens": jax.ShapeDtypeStruct((B, S - VLM_PATCHES), jnp.int32),
            "frontend_embeds": jax.ShapeDtypeStruct(
                (B, VLM_PATCHES, cfg.d_model), dt
            ),
        }
    if cfg.family == "audio":
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "frontend_embeds": jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), dt
            ),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def make_demo_batch(cfg: ModelConfig, key, batch: int, seq: int) -> dict:
    """Concrete random batch for smoke tests / examples."""
    k1, k2 = jax.random.split(key)
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    }
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        npatch = min(8, seq // 2)
        out["tokens"] = out["tokens"][:, : seq - npatch]
        out["frontend_embeds"] = (
            jax.random.normal(k2, (batch, npatch, cfg.d_model)) * 0.02
        ).astype(dt)
    if cfg.family == "audio":
        out["frontend_embeds"] = (
            jax.random.normal(k2, (batch, cfg.encoder_seq, cfg.d_model)) * 0.02
        ).astype(dt)
    return out
