"""GQA/MQA attention with causal + sliding-window masking, RoPE/M-RoPE,
contiguous KV caches (ring-buffered under SWA so decode memory is bounded).

Two math paths: ``xla`` (pure jnp, used for dry-run/roofline -- XLA fuses this
well on TPU) and ``pallas`` (the flash_attention kernel in repro/kernels,
validated against the same reference). Selected by cfg.attention_impl.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers as L


def attn_params(cfg, key, *, cross=False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    pd = L.param_dtype(cfg)
    p = {
        "wq": L.dense_init(ks[0], (d, H, hd), pd, fan_in=d),
        "wk": L.dense_init(ks[1], (d, KV, hd), pd, fan_in=d),
        "wv": L.dense_init(ks[2], (d, KV, hd), pd, fan_in=d),
        "wo": L.dense_init(ks[3], (H, hd, d), pd, fan_in=H * hd),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H, hd), pd)
        p["bk"] = jnp.zeros((KV, hd), pd)
        p["bv"] = jnp.zeros((KV, hd), pd)
    return p


def _project_qkv(cfg, p, xq, xkv):
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", xkv, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", xkv, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.kv_replication > 1:
        # kv-head replication: duplicate kv heads so caches shard TP-ways and
        # every device's q-head block sees exactly its own kv head (DESIGN.md §6)
        k = jnp.repeat(k, cfg.kv_replication, axis=2)
        v = jnp.repeat(v, cfg.kv_replication, axis=2)
    return q, k, v


def sdpa(cfg, q, k, v, *, q_positions=None, k_positions=None, causal=True,
         window=0, k_valid=None):
    """Scaled-dot-product GQA attention (the `xla` path; also the kernels' oracle).

    q [B,S,H,hd]; k,v [B,T,KV,hd]. Masks: causal (by absolute positions),
    sliding window (0 = full), and k_valid [B,T] (cache validity)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if q_positions is None:
        q_positions = jnp.arange(S)[None]
    if k_positions is None:
        k_positions = jnp.arange(T)[None]
    qp = q_positions[:, None, None, :, None]  # [B,1,1,S,1]
    kp = k_positions[:, None, None, None, :]  # [B,1,1,1,T]
    mask = jnp.ones((B, 1, 1, S, T), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    if k_valid is not None:
        mask &= k_valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, H, hd)


def chunked_sdpa(cfg, q, k, v, *, causal=True, window=0, block_q=1024,
                 block_k=1024):
    """Online-softmax (flash-style) attention in pure lax: scan over query
    blocks, remat'd inner scan over key blocks. Peak memory O(block_q*block_k)
    instead of O(S*T) -- required for the 32k cells. Same math as :func:`sdpa`
    (tested); block-masked waste on causal lower blocks is accounted for in the
    roofline (EXPERIMENTS.md §Roofline note)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = min(block_q, S), min(block_k, T)
    nq, nk = S // bq, T // bk
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    NEG = jnp.float32(-1e30)

    qb = q.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def one_q_block(args):
        qi, idx = args
        qpos = idx * bq + jnp.arange(bq)

        def kv_step(carry, j):
            acc, m, l = carry
            kj = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=1)
            s = jnp.einsum("bqkgh,btkh->bkgqt", qi, kj).astype(jnp.float32) * scale
            kpos = j * bk + jnp.arange(bk)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(q.dtype), vj
            ).astype(jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)
        m0 = jnp.full((B, KV, G, bq), NEG)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(nk)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)  # [B,KV,G,bq,hd]

    outs = jax.lax.map(jax.checkpoint(one_q_block), (qb, jnp.arange(nq)))
    # [nq,B,KV,G,bq,hd] -> [B,S,H,hd]
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, hd)


def _attend(cfg, q, k, v, **kw):
    if cfg.attention_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa

        if kw.get("k_valid") is None and q.shape[1] == k.shape[1]:
            return fa.flash_attention(
                q, k, v, causal=kw.get("causal", True), window=kw.get("window", 0)
            )
    S, T = q.shape[1], k.shape[1]
    if cfg.attn_chunk and S >= cfg.attn_chunk and T >= cfg.attn_chunk \
            and kw.get("k_valid") is None:
        return chunked_sdpa(
            cfg, q, k, v,
            causal=kw.get("causal", True), window=kw.get("window", 0),
            block_q=cfg.attn_chunk, block_k=cfg.attn_chunk,
        )
    return sdpa(cfg, q, k, v, **kw)


def self_attention(cfg, p, x, positions, *, causal=True):
    """Full-sequence self-attention (train / prefill / encoder)."""
    q, k, v = _project_qkv(cfg, p, x, x)
    if cfg.rope_theta:
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    out = _attend(cfg, q, k, v, causal=causal, window=cfg.sliding_window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# KV cache (decode). Under SWA the cache is a ring buffer of size `window`.
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jax.Array        # [B, T, KV, hd]
    v: jax.Array        # [B, T, KV, hd]
    length: jax.Array   # int32: absolute number of tokens written so far


def init_cache(cfg, batch, max_len, dtype, prefill_len=0):
    T = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    KV = cfg.num_kv_heads * cfg.kv_replication
    hd = cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, T, KV, hd), dtype),
        v=jnp.zeros((batch, T, KV, hd), dtype),
        length=jnp.int32(prefill_len),
    )


def decode_attention(cfg, p, x, cache: KVCache):
    """One-token decode step. x: [B, 1, d]. Keys are stored pre-rotated, so the
    ring buffer needs no position bookkeeping (RoPE is relative)."""
    B = x.shape[0]
    T = cache.k.shape[1]
    pos = cache.length                     # absolute position of the new token
    q, k, v = _project_qkv(cfg, p, x, x)
    if cfg.rope_theta:
        pp = jnp.broadcast_to(pos[None, None], (B, 1))
        q = L.apply_rope(q, pp, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_rope(k, pp, cfg.rope_theta, cfg.mrope_sections)
    slot = jnp.where(cfg.sliding_window > 0, pos % T, jnp.minimum(pos, T - 1))
    kc = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
    filled = jnp.minimum(pos + 1, T)  # ring buffer: slot order is irrelevant
    valid = jnp.arange(T)[None] < filled
    out = sdpa(
        cfg, q, kc, vc,
        causal=False,                 # causality via the validity mask
        window=0,
        k_valid=jnp.broadcast_to(valid, (B, T)),
    )
    new_cache = KVCache(k=kc, v=vc, length=pos + 1)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


def prefill_attention(cfg, p, x, positions, max_len=None):
    """Prefill: full self-attention + return the populated cache (padded to
    ``max_len`` slots so decode can append)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, x)
    if cfg.rope_theta:
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    out = _attend(cfg, q, k, v, causal=True, window=cfg.sliding_window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    max_len = max_len or S
    if cfg.sliding_window and cfg.sliding_window < S:
        W = cfg.sliding_window
        k_keep, v_keep = k[:, -W:], v[:, -W:]
        # ring-align: token at absolute position p sits at slot p % W
        shift = S % W
        k_keep = jnp.roll(k_keep, shift, axis=1)
        v_keep = jnp.roll(v_keep, shift, axis=1)
        cache = KVCache(k=k_keep, v=v_keep, length=jnp.int32(S))
    else:
        pad = max(0, max_len - S)
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache = KVCache(k=k, v=v, length=jnp.int32(S))
    return y, cache


def cross_attention(cfg, p, x, enc_kv, positions=None):
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    k, v = enc_kv
    out = sdpa(cfg, q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def encode_cross_kv(cfg, p, enc_out):
    dt = enc_out.dtype
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v
