"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060).

Train/prefill use the chunked SSD algorithm: quadratic attention-like math
inside chunks of length Q + a linear state recurrence across chunks (one
lax.scan over S/Q chunks carrying the [B,H,N,P] state). Decode is the O(1)
recurrent update. The in-chunk compute is also available as a Pallas kernel
(repro/kernels/ssd_scan) validated against the jnp path here.

Layout: x [B,S,H,P] (H heads, P=head_dim), B/C [B,S,G,N] (G groups, N=state),
dt [B,S,H], A = -exp(A_log) [H], skip D [H].
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers as L


def ssm_params(cfg, key):
    d = cfg.d_model
    din, ns, g, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    conv_dim = din + 2 * g * ns
    ks = jax.random.split(key, 5)
    pd = L.param_dtype(cfg)
    return {
        # fused in-projection: [z (din), xBC (din + 2*g*ns), dt (h)]
        "in_proj": L.dense_init(ks[0], (d, 2 * din + 2 * g * ns + h), pd, fan_in=d),
        "conv_w": L.dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), pd,
                               fan_in=cfg.ssm_conv_width),
        "conv_b": jnp.zeros((conv_dim,), pd),
        "dt_bias": jnp.zeros((h,), pd),
        "A_log": jnp.zeros((h,), pd),
        "D": jnp.ones((h,), pd),
        "norm_scale": jnp.zeros((din,), pd),
        "out_proj": L.dense_init(ks[2], (din, d), pd, fan_in=din),
    }


def _split_proj(cfg, proj):
    din, ns, g, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    z = proj[..., :din]
    xBC = proj[..., din : 2 * din + 2 * g * ns]
    dt = proj[..., 2 * din + 2 * g * ns :]
    return z, xBC, dt


def _split_xbc(cfg, xBC):
    din, ns, g = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_groups
    x = xBC[..., :din]
    Bm = xBC[..., din : din + g * ns]
    Cm = xBC[..., din + g * ns :]
    return x, Bm, Cm


def _causal_conv(cfg, p, xBC):
    """Depthwise causal conv1d + silu over [B, S, conv_dim]."""
    W = cfg.ssm_conv_width
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xBC.shape[1], :] * p["conv_w"].astype(xBC.dtype)[i][None, None]
        for i in range(W)
    )
    return jax.nn.silu(out + p["conv_b"].astype(xBC.dtype))


def ssd_chunked(cfg, x, dt, A, Bm, Cm, init_state=None):
    """Chunked SSD. x [B,S,H,P], dt [B,S,H] (post-softplus), A [H] (<0),
    Bm/Cm [B,S,G,N]. Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    rep = H // G

    def chunk_view(t):  # [B,S,...] -> [B,nc,Q,...]
        return t.reshape((Bsz, nc, Q) + t.shape[2:])

    xc, dtc = chunk_view(x), chunk_view(dt)
    Bc, Cc = chunk_view(Bm), chunk_view(Cm)

    s0 = (jnp.zeros((Bsz, H, N, P), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    ii = jnp.arange(Q)
    tri = ii[:, None] >= ii[None, :]

    def body(state, inp):
        """Process ONE chunk: intra-chunk quadratic part + inter-chunk state.
        All O(Q^2) intermediates live only inside this body (memory-bounded;
        remat'd in the backward pass)."""
        x_n, dt_n, B_n, C_n = inp          # [B,Q,H,P],[B,Q,H],[B,Q,G,N],[B,Q,G,N]
        la = (dt_n * A[None, None, :]).astype(jnp.float32)   # [B,Q,H]
        cl = jnp.cumsum(la, axis=1)                          # [B,Q,H]
        clh = cl.transpose(0, 2, 1)                          # [B,H,Q]
        # intra: scores[i,j] = (C_i.B_j) exp(cl_i - cl_j) dt_j for j<=i
        CB = jnp.einsum("bqgs,bkgs->bgqk", C_n, B_n)         # [B,G,Q,Q]
        CB = jnp.broadcast_to(
            CB[:, :, None], (Bsz, G, rep, Q, Q)
        ).reshape(Bsz, H, Q, Q)
        decay = jnp.exp(clh[..., :, None] - clh[..., None, :])
        scores = CB.astype(jnp.float32) * decay * dt_n.transpose(0, 2, 1)[:, :, None, :]
        scores = jnp.where(tri[None, None], scores, 0.0)
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", scores.astype(x.dtype), x_n)
        # inter: y_inter[i] = C_i . (state_prev * exp(cl_i))
        Ch = jnp.broadcast_to(
            C_n.reshape(Bsz, Q, G, 1, N), (Bsz, Q, G, rep, N)
        ).reshape(Bsz, Q, H, N)
        y_inter = jnp.einsum("bqhs,bhsp,bqh->bqhp",
                             Ch.astype(jnp.float32), state, jnp.exp(cl))
        # state update: state = state * exp(cl_last) + sum_j exp(cl_last-cl_j) dt_j B_j x_j
        w = jnp.exp(cl[:, -1:, :] - cl) * dt_n               # [B,Q,H]
        Bh = jnp.broadcast_to(
            B_n.reshape(Bsz, Q, G, 1, N), (Bsz, Q, G, rep, N)
        ).reshape(Bsz, Q, H, N)
        st_n = jnp.einsum("bqh,bqhs,bqhp->bhsp",
                          w.astype(jnp.float32), Bh.astype(jnp.float32),
                          x_n.astype(jnp.float32))
        state = state * jnp.exp(cl[:, -1])[:, :, None, None] + st_n
        return state, (y_intra + y_inter.astype(x.dtype))

    xs = (
        xc.swapaxes(0, 1), dtc.swapaxes(0, 1),
        Bc.swapaxes(0, 1), Cc.swapaxes(0, 1),
    )
    final_state, y = jax.lax.scan(jax.checkpoint(body), s0, xs)
    y = y.swapaxes(0, 1).reshape(Bsz, S, H, P)
    return y, final_state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMCache:
    conv: jax.Array    # [B, W-1, conv_dim] trailing conv inputs
    state: jax.Array   # [B, H, N, P] SSM state (f32)


def init_ssm_cache(cfg, batch, dtype):
    din, ns, g = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_groups
    conv_dim = din + 2 * g * ns
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        state=jnp.zeros((batch, cfg.ssm_heads, ns, cfg.ssm_head_dim), jnp.float32),
    )


def apply_ssm(cfg, p, u, *, init_state=None):
    """Full-sequence Mamba2 block: u [B,S,D] -> ([B,S,D], SSMCache).
    The returned cache (final state + conv tail) makes this the prefill path."""
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    dt_ = u.dtype
    proj = jnp.einsum("bsd,dk->bsk", u, p["in_proj"].astype(dt_))
    z, xBC_raw, dtv = _split_proj(cfg, proj)
    conv_tail = xBC_raw[:, -(cfg.ssm_conv_width - 1):, :]
    xBC = _causal_conv(cfg, p, xBC_raw)
    x, Bm, Cm = _split_xbc(cfg, xBC)
    Bsz, S = x.shape[0], x.shape[1]
    x = x.reshape(Bsz, S, H, P)
    Bm = Bm.reshape(Bsz, S, G, N)
    Cm = Cm.reshape(Bsz, S, G, N)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(cfg, x, dtv, A, Bm, Cm, init_state=init_state)
    y = y + x * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(Bsz, S, cfg.ssm_d_inner)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_))
    return out, SSMCache(conv=conv_tail, state=final_state)


def decode_ssm(cfg, p, u, cache: SSMCache):
    """One-token recurrent update. u: [B, 1, D]."""
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    dt_ = u.dtype
    Bsz = u.shape[0]
    proj = jnp.einsum("bsd,dk->bsk", u, p["in_proj"].astype(dt_))
    z, xBC, dtv = _split_proj(cfg, proj)
    # conv over [cache | new token]
    window = jnp.concatenate([cache.conv, xBC], axis=1)       # [B, W, conv]
    conv_out = jnp.einsum(
        "bwc,wc->bc", window, p["conv_w"].astype(dt_)
    ) + p["conv_b"].astype(dt_)
    xBC1 = jax.nn.silu(conv_out)[:, None, :]
    x, Bm, Cm = _split_xbc(cfg, xBC1)
    x = x.reshape(Bsz, H, P)
    Bm = Bm.reshape(Bsz, G, N)
    Cm = Cm.reshape(Bsz, G, N)
    dtv = jax.nn.softplus(
        dtv[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                         # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dtv * A[None])                                # [B,H]
    rep = H // G
    Bh = jnp.broadcast_to(
        Bm[:, :, None, :], (Bsz, G, rep, N)
    ).reshape(Bsz, H, N).astype(jnp.float32)
    Ch = jnp.broadcast_to(
        Cm[:, :, None, :], (Bsz, G, rep, N)
    ).reshape(Bsz, H, N).astype(jnp.float32)
    state = cache.state * da[:, :, None, None] + jnp.einsum(
        "bh,bhs,bhp->bhsp", dtv, Bh, x.astype(jnp.float32)
    )
    y = jnp.einsum("bhs,bhsp->bhp", Ch, state).astype(dt_)
    y = y + x * p["D"].astype(dt_)[None, :, None]
    y = y.reshape(Bsz, 1, cfg.ssm_d_inner)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_))
    return out, SSMCache(conv=window[:, 1:], state=state)
