"""Unified decoder-only transformer LM: dense / GQA / MQA / SWA / MoE / M-RoPE
(covers qwen2-vl, granite-moe, mixtral, granite-20b, command-r, stablelm,
mistral-large). Layers are stacked on a leading axis and driven by lax.scan
(compact HLO, O(1) compile in depth); each block is optionally remat'd."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as A
from . import layers as L
from . import moe as M


def init_block_params(cfg, key):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.norm_params(cfg, cfg.d_model),
        "attn": A.attn_params(cfg, k1),
        "ln2": L.norm_params(cfg, cfg.d_model),
    }
    if cfg.num_experts:
        p["moe"] = M.moe_params(cfg, k2)
    else:
        p["mlp"] = L.mlp_params(cfg, k2, cfg.d_model, cfg.d_ff)
    return p


def init_params(cfg, key):
    ke, kl, ko = jax.random.split(key, 3)
    pd = L.param_dtype(cfg)
    params = {
        "embed": L.embed_init(ke, (cfg.padded_vocab, cfg.d_model), pd),
        "blocks": jax.vmap(lambda k: init_block_params(cfg, k))(
            jax.random.split(kl, cfg.num_layers)
        ),
        "final_norm": L.norm_params(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(
            ko, (cfg.d_model, cfg.padded_vocab), pd, fan_in=cfg.d_model
        )
    return params


def _ffn(cfg, p, h):
    if cfg.num_experts:
        return M.apply_moe(cfg, p["moe"], h)
    return L.apply_mlp(cfg, p["mlp"], h)


def _block_fwd(cfg, p, x, positions):
    h = x + A.self_attention(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x), positions)
    return h + _ffn(cfg, p, L.apply_norm(cfg, p["ln2"], h))


def _embed_inputs(cfg, params, batch):
    """Token embeddings, with optional stubbed frontend embeddings PREPENDED
    (qwen2-vl patch embeds). Returns (x [B,S,D], positions [B,S])."""
    dt = L.compute_dtype(cfg)
    tokens = batch["tokens"]
    x = params["embed"].astype(dt)[tokens]
    if batch.get("frontend_embeds") is not None:
        fe = batch["frontend_embeds"].astype(dt)
        x = jnp.concatenate([fe, x], axis=1)
    B, S, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def logits_from_hidden(cfg, params, h):
    dt = h.dtype
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(dt))
    return jnp.einsum("bsd,dv->bsv", h, params["unembed"].astype(dt))


def forward(cfg, params, batch):
    """Training/eval forward over the full sequence -> logits [B,S,Vp]."""
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    x, positions = _embed_inputs(cfg, params, batch)

    def block(h, p):
        return _block_fwd(cfg, p, h, positions), None

    fn = jax.checkpoint(block) if cfg.remat else block
    x, _ = scan_or_unroll(cfg, fn, x, params["blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return logits_from_hidden(cfg, params, x)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def stack_layer_tree(cfg, tree, n):
    """Stacked [L, ...] leaves when scanning; a LIST of per-layer trees when
    unrolled -- separate argument buffers let XLA alias donated cache inputs
    to their dynamic-update-sliced outputs (zero-copy decode)."""
    if cfg.scan_layers:
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape)
            if getattr(a, "ndim", 0)
            else jnp.full((n,), a),
            tree,
        )
    return [jax.tree_util.tree_map(jnp.array, tree) for _ in range(n)]


def unrolled_decode(body, x, params_stacked, caches_list):
    """Python-loop decode over per-layer (param-slice, cache) pairs."""
    outs = []
    for i, cache in enumerate(caches_list):
        p_i = jax.tree_util.tree_map(lambda a: a[i], params_stacked)
        x, c = body(x, (p_i, cache))
        outs.append(c)
    return x, outs


def init_decode_state(cfg, batch, max_len, prefill_len=0):
    dt = L.compute_dtype(cfg)
    cache = A.init_cache(cfg, batch, max_len, dt, prefill_len)
    return stack_layer_tree(cfg, cache, cfg.num_layers)


def scan_or_unroll(cfg, body, carry, xs):
    """lax.scan when cfg.scan_layers (compact HLO) else a python loop
    (exact per-layer cost in the dry-run HLO; DESIGN.md §7)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def prefill(cfg, params, batch, max_len):
    """Run the full prompt, returning (last-position logits, stacked caches)."""
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    x, positions = _embed_inputs(cfg, params, batch)

    def block(h, p):
        hn = L.apply_norm(cfg, p["ln1"], h)
        y, cache = A.prefill_attention(cfg, p["attn"], hn, positions, max_len)
        h = h + y
        h = h + _ffn(cfg, p, L.apply_norm(cfg, p["ln2"], h))
        return h, cache

    x, caches = scan_or_unroll(cfg, block, x, params["blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    return logits_from_hidden(cfg, params, x), caches


def decode_step(cfg, params, caches, tokens):
    """One-token decode: tokens [B, 1] -> (logits [B,1,Vp], new caches)."""
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    dt = L.compute_dtype(cfg)
    x = params["embed"].astype(dt)[tokens]

    def block(h, inp):
        p, cache = inp
        hn = L.apply_norm(cfg, p["ln1"], h)
        y, cache = A.decode_attention(cfg, p["attn"], hn, cache)
        h = h + y
        h = h + _ffn(cfg, p, L.apply_norm(cfg, p["ln2"], h))
        return h, cache

    if isinstance(caches, list):
        x, caches = unrolled_decode(block, x, params["blocks"], caches)
    else:
        x, caches = jax.lax.scan(block, x, (params["blocks"], caches))
    x = L.apply_norm(cfg, params["final_norm"], x)
    return logits_from_hidden(cfg, params, x), caches
