"""The paper's application models (Sec. 6.2-6.4), in JAX: kNN classification,
linear regression, multinomial Naive Bayes. Each is (re)trained on the current
realized sample -- fixed-capacity arrays + validity mask, so retraining and
prediction are jit-able."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "num_classes"))
def knn_predict(train_x, train_y, valid, query_x, *, k: int = 7,
                num_classes: int = 100):
    """Majority vote over the k nearest (Euclidean) valid training points."""
    d2 = jnp.sum(
        (query_x[:, None, :] - train_x[None, :, :]) ** 2, axis=-1
    )
    d2 = jnp.where(valid[None, :], d2, jnp.inf)
    _, idx = jax.lax.top_k(-d2, k)                       # [Q, k]
    votes = train_y[idx]                                 # [Q, k]
    # guard: neighbours that are invalid (tiny samples) vote for class -1
    ok = jnp.take_along_axis(jnp.broadcast_to(valid[None], d2.shape), idx, 1)
    onehot = jax.nn.one_hot(votes, num_classes) * ok[..., None]
    return jnp.argmax(onehot.sum(axis=1), axis=-1).astype(jnp.int32)


@jax.jit
def linreg_fit(train_x, train_y, valid):
    """Least squares (with intercept) over the valid rows (closed form)."""
    w = valid.astype(jnp.float32)
    X = jnp.concatenate([train_x, jnp.ones_like(train_x[:, :1])], axis=1)
    Xw = X * w[:, None]
    A = Xw.T @ X + 1e-6 * jnp.eye(X.shape[1])
    b = Xw.T @ train_y
    return jnp.linalg.solve(A, b)


@jax.jit
def linreg_predict(coef, query_x):
    X = jnp.concatenate([query_x, jnp.ones_like(query_x[:, :1])], axis=1)
    return X @ coef


@functools.partial(jax.jit, static_argnames=("num_classes",))
def nb_fit(train_counts, train_y, valid, *, num_classes: int = 2):
    """Multinomial Naive Bayes with Laplace smoothing over bag-of-words."""
    w = valid.astype(jnp.float32)
    onehot = jax.nn.one_hot(train_y, num_classes) * w[:, None]   # [N, C]
    class_counts = onehot.sum(axis=0)                            # [C]
    word_counts = onehot.T @ train_counts                        # [C, V]
    log_prior = jnp.log(class_counts + 1.0) - jnp.log(
        jnp.sum(class_counts) + num_classes
    )
    log_like = jnp.log(word_counts + 1.0) - jnp.log(
        word_counts.sum(axis=1, keepdims=True) + train_counts.shape[1]
    )
    return log_prior, log_like


@jax.jit
def nb_predict(params, query_counts):
    log_prior, log_like = params
    scores = query_counts @ log_like.T + log_prior[None]
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


def expected_shortfall(values, frac: float) -> float:
    """z% ES: mean of the worst z% of cases (paper Sec. 6.2, [27])."""
    import numpy as np

    v = np.sort(np.asarray(values))[::-1]  # worst (largest error) first
    k = max(1, int(round(frac * len(v))))
    return float(v[:k].mean())
