"""repro.models -- pure-JAX model zoo (pytree params, lax.scan over layers).

Families: dense/GQA/MQA/SWA transformer, MoE, Mamba2 SSD, Zamba2-style hybrid,
Whisper-style encoder-decoder, Qwen2-VL backbone (M-RoPE + frontend stub).
Entry point: :func:`repro.models.zoo.build`.
"""
from . import zoo  # noqa: F401
