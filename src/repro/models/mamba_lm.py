"""Pure Mamba2 (SSD) language model -- attention-free (mamba2-370m)."""
from __future__ import annotations

import jax

from . import layers as L
from . import ssm as S
from . import transformer as T


def init_params(cfg, key):
    ke, km, ko = jax.random.split(key, 3)
    pd = L.param_dtype(cfg)
    params = {
        "embed": L.embed_init(ke, (cfg.padded_vocab, cfg.d_model), pd),
        "blocks": jax.vmap(
            lambda k: {"ln": L.norm_params(cfg, cfg.d_model),
                       "ssm": S.ssm_params(cfg, k)}
        )(jax.random.split(km, cfg.num_layers)),
        "final_norm": L.norm_params(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(
            ko, (cfg.d_model, cfg.padded_vocab), pd, fan_in=cfg.d_model
        )
    return params


def forward(cfg, params, batch):
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    x, _ = T._embed_inputs(cfg, params, batch)

    def layer(h, p):
        y, _ = S.apply_ssm(cfg, p["ssm"], L.apply_norm(cfg, p["ln"], h))
        return h + y, None

    fn = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = T.scan_or_unroll(cfg, fn, x, params["blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return T.logits_from_hidden(cfg, params, x)


def prefill(cfg, params, batch, max_len):
    """Run the full prompt through the chunked SSD path, returning
    (last-position logits, per-layer SSMCaches). max_len unused: SSM state
    is O(1) in context length."""
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    del max_len
    x, _ = T._embed_inputs(cfg, params, batch)

    def layer(h, p):
        y, cache = S.apply_ssm(cfg, p["ssm"], L.apply_norm(cfg, p["ln"], h))
        return h + y, cache

    x, caches = T.scan_or_unroll(cfg, layer, x, params["blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    return T.logits_from_hidden(cfg, params, x), caches


def init_decode_state(cfg, batch, max_len, prefill_len=0):
    del max_len, prefill_len  # SSM state is O(1) in context length
    dt = L.compute_dtype(cfg)
    c = S.init_ssm_cache(cfg, batch, dt)
    return T.stack_layer_tree(cfg, c, cfg.num_layers)


def decode_step(cfg, params, caches, tokens):
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    dt = L.compute_dtype(cfg)
    x = params["embed"].astype(dt)[tokens]

    def layer(h, inp):
        p, cache = inp
        y, cache = S.decode_ssm(cfg, p["ssm"], L.apply_norm(cfg, p["ln"], h), cache)
        return h + y, cache

    if isinstance(caches, list):
        x, caches = T.unrolled_decode(layer, x, params["blocks"], caches)
    else:
        x, caches = jax.lax.scan(layer, x, (params["blocks"], caches))
    x = L.apply_norm(cfg, params["final_norm"], x)
    return T.logits_from_hidden(cfg, params, x), caches
