"""Whisper-style encoder-decoder (audio): bidirectional encoder over stubbed
frame embeddings + causal decoder with cross-attention. Sinusoidal absolute
positions (DESIGN.md notes the deviation from learned decoder positions)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as A
from . import layers as L
from . import transformer as T


def init_params(cfg, key):
    ke, kenc, kdec, ko = jax.random.split(key, 4)
    pd = L.param_dtype(cfg)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": L.norm_params(cfg, cfg.d_model),
            "attn": A.attn_params(cfg, k1),
            "ln2": L.norm_params(cfg, cfg.d_model),
            "mlp": L.mlp_params(cfg, k2, cfg.d_model, cfg.d_ff),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": L.norm_params(cfg, cfg.d_model),
            "self_attn": A.attn_params(cfg, k1),
            "ln_x": L.norm_params(cfg, cfg.d_model),
            "cross_attn": A.attn_params(cfg, k2),
            "ln2": L.norm_params(cfg, cfg.d_model),
            "mlp": L.mlp_params(cfg, k3, cfg.d_model, cfg.d_ff),
        }

    return {
        "embed": L.embed_init(ke, (cfg.padded_vocab, cfg.d_model), pd),
        "enc": jax.vmap(enc_block)(jax.random.split(kenc, cfg.encoder_layers)),
        "enc_norm": L.norm_params(cfg, cfg.d_model),
        "dec": jax.vmap(dec_block)(jax.random.split(kdec, cfg.num_layers)),
        "final_norm": L.norm_params(cfg, cfg.d_model),
    }


def encode(cfg, params, frame_embeds):
    """frame_embeds: [B, T_enc, D] (stubbed conv frontend output)."""
    dt = L.compute_dtype(cfg)
    x = frame_embeds.astype(dt)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)[None]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def block(h, p):
        hn = L.apply_norm(cfg, p["ln1"], h)
        h = h + A.self_attention(cfg, p["attn"], hn, positions, causal=False)
        h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        return h, None

    fn = jax.checkpoint(block) if cfg.remat else block
    x, _ = T.scan_or_unroll(cfg, fn, x, params["enc"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def _dec_block(cfg, p, h, positions, enc_kv):
    hn = L.apply_norm(cfg, p["ln1"], h)
    h = h + A.self_attention(cfg, p["self_attn"], hn, positions, causal=True)
    hx = L.apply_norm(cfg, p["ln_x"], h)
    h = h + A.cross_attention(cfg, p["cross_attn"], hx, enc_kv)
    return h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))


def forward(cfg, params, batch):
    """Teacher-forced training: frame embeds -> encoder; tokens -> decoder."""
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    dt = L.compute_dtype(cfg)
    enc_out = encode(cfg, params, batch["frontend_embeds"])
    tokens = batch["tokens"]
    x = params["embed"].astype(dt)[tokens]
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)[None]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def block(h, p):
        enc_kv = A.encode_cross_kv(cfg, p["cross_attn"], enc_out)
        return _dec_block(cfg, p, h, positions, enc_kv), None

    fn = jax.checkpoint(block) if cfg.remat else block
    x, _ = T.scan_or_unroll(cfg, fn, x, params["dec"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return T.logits_from_hidden(cfg, params, x)


def prefill(cfg, params, batch, max_len):
    """Encode the (stubbed) audio frames, teacher-force the prompt through the
    decoder, return (last logits, {self-attn caches, cross K/V})."""
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    dt = L.compute_dtype(cfg)
    enc_out = encode(cfg, params, batch["frontend_embeds"])
    tokens = batch["tokens"]
    x = params["embed"].astype(dt)[tokens]
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)[None]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def block(h, p):
        enc_kv = A.encode_cross_kv(cfg, p["cross_attn"], enc_out)
        hn = L.apply_norm(cfg, p["ln1"], h)
        y, kv_c = A.prefill_attention(cfg, p["self_attn"], hn, positions, max_len)
        h = h + y
        hx = L.apply_norm(cfg, p["ln_x"], h)
        h = h + A.cross_attention(cfg, p["cross_attn"], hx, enc_kv)
        h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        return h, (kv_c, enc_kv)

    x, (kv, cross) = T.scan_or_unroll(cfg, block, x, params["dec"])
    x = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    return T.logits_from_hidden(cfg, params, x), {"kv": kv, "cross": cross}


def init_decode_state(cfg, batch, max_len, prefill_len=0, enc_out=None):
    """Decoder self-attn caches + precomputed per-layer cross K/V."""
    dt = L.compute_dtype(cfg)
    kv = A.init_cache(cfg, batch, max_len, dt, prefill_len)
    kv = T.stack_layer_tree(cfg, kv, cfg.num_layers)
    KV = cfg.num_kv_heads * cfg.kv_replication
    hd = cfg.resolved_head_dim
    if cfg.scan_layers:
        cross = (
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq, KV, hd), dt),
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq, KV, hd), dt),
        )
    else:
        cross = [
            (jnp.zeros((batch, cfg.encoder_seq, KV, hd), dt),
             jnp.zeros((batch, cfg.encoder_seq, KV, hd), dt))
            for _ in range(cfg.num_layers)
        ]
    return {"kv": kv, "cross": cross}


def precompute_cross(cfg, params, enc_out):
    def per_layer(p):
        return A.encode_cross_kv(cfg, p["cross_attn"], enc_out)

    return jax.vmap(per_layer, in_axes=(0,))(params["dec"])


def decode_step(cfg, params, caches, tokens):
    from . import zoo as _zoo
    params = _zoo.precast(cfg, params)
    dt = L.compute_dtype(cfg)
    x = params["embed"].astype(dt)[tokens]
    unstacked = isinstance(caches["kv"], list)
    pos = caches["kv"][0].length if unstacked else caches["kv"].length[0]
    x = x + L.sinusoidal_positions(1, cfg.d_model, offset=pos).astype(dt)[None]

    def block(h, inp):
        p, kv_c, cross_k, cross_v = inp
        hn = L.apply_norm(cfg, p["ln1"], h)
        y, kv_c = A.decode_attention(cfg, p["self_attn"], hn, kv_c)
        h = h + y
        hx = L.apply_norm(cfg, p["ln_x"], h)
        h = h + A.cross_attention(cfg, p["cross_attn"], hx, (cross_k, cross_v))
        h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        return h, kv_c

    if unstacked:
        kv = []
        for i, (kv_c, (ck_i, cv_i)) in enumerate(zip(caches["kv"], caches["cross"])):
            p_i = jax.tree_util.tree_map(lambda a: a[i], params["dec"])
            x, kv_c = block(x, (p_i, kv_c, ck_i, cv_i))
            kv.append(kv_c)
    else:
        ck, cv = caches["cross"]
        x, kv = jax.lax.scan(block, x, (params["dec"], caches["kv"], ck, cv))
    x = L.apply_norm(cfg, params["final_norm"], x)
    return T.logits_from_hidden(cfg, params, x), {"kv": kv, "cross": caches["cross"]}
