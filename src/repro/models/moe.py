"""Mixture-of-Experts layer: top-k routing with capacity-bounded sort-based
dispatch (fixed shapes, honest active-expert FLOPs for the roofline).

Dispatch is the standard TPU-friendly scheme: flatten tokens, sort assignments
by expert id, compute position-in-expert by a segment cumsum, scatter into a
[E, C, D] buffer (drop beyond capacity), run per-expert einsums, scatter-add
back weighted by the router gate. Experts' ff dims are tensor-sharded (none of
the assigned expert counts divide the 16-way model axis; DESIGN.md §5)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L


def moe_params(cfg, key):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    pd = L.param_dtype(cfg)
    return {
        "router": L.dense_init(ks[0], (d, e), pd),
        "wg": L.dense_init(ks[1], (e, d, f), pd, fan_in=d),
        "wi": L.dense_init(ks[2], (e, d, f), pd, fan_in=d),
        "wo": L.dense_init(ks[3], (e, f, d), pd, fan_in=f),
    }


def capacity(cfg, n_tokens: int) -> int:
    c = int(cfg.moe_capacity_factor * n_tokens * cfg.num_experts_per_tok
            / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)


def apply_moe(cfg, p, x):
    """x: [B, S, D] -> [B, S, D]. Dispatch runs independently per group
    (groups partition the flattened token axis and align with DP shards, so
    the [E, C, D] buffers stay batch-sharded under SPMD)."""
    B, S, D = x.shape
    G = max(1, min(cfg.moe_groups, B))
    xf = x.reshape(G, (B * S) // G, D)
    out = jax.vmap(lambda xg: _moe_group(cfg, p, xg))(xf)
    return out.reshape(B, S, D)


def _moe_group(cfg, p, xf):
    """xf: [N, D] -> [N, D]."""
    N, D = xf.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = capacity(cfg, N)
    dt = xf.dtype

    logits = jnp.einsum("nd,de->ne", xf, p["router"].astype(dt)).astype(jnp.float32)
    gate_all = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(gate_all, K)          # [N, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based position-in-expert ------------------------------------
    flat_e = eidx.reshape(-1)                          # [N*K]
    flat_tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    # position within its expert group = index - start_of_group
    idx = jnp.arange(N * K, dtype=jnp.int32)
    seg_start = jnp.full((E,), N * K, jnp.int32).at[se].min(idx, mode="drop")
    pos_in_e = idx - seg_start[se]
    keep = pos_in_e < C                                # capacity drop
    dest = jnp.where(keep, se * C + pos_in_e, E * C)   # E*C => dropped

    # ---- dispatch -----------------------------------------------------------
    xe = jnp.zeros((E * C, D), dt).at[dest].set(xf[flat_tok[order]], mode="drop")
    xe = xe.reshape(E, C, D)

    # ---- expert compute -------------------------------------------------------
    if cfg.act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))
        h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt)))
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt)).reshape(E * C, D)

    # ---- combine ---------------------------------------------------------------
    src = jnp.where(keep, dest, 0)
    contrib = ye[src] * jnp.where(keep, flat_gate[order], 0.0)[:, None].astype(dt)
    return jnp.zeros((N, D), dt).at[flat_tok[order]].add(contrib)
