"""Shared layers: norms, embeddings, rotary (RoPE + M-RoPE), MLPs, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def compute_dtype(cfg):
    return jnp.dtype(cfg.dtype)


def param_dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_params(cfg, d):
    if cfg.act == "gelu":  # LayerNorm families (whisper)
        return {"scale": jnp.ones((d,), param_dtype(cfg)),
                "bias": jnp.zeros((d,), param_dtype(cfg))}
    return {"scale": jnp.zeros((d,), param_dtype(cfg))}  # RMSNorm (scale-centered)


def apply_norm(cfg, p, x):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# positions: RoPE, M-RoPE (qwen2-vl), sinusoidal absolute (whisper)
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta, mrope_sections=()):
    """x: [B, S, H, hd]; positions: [B, S] (broadcast to 3 streams for M-RoPE)
    or [3, B, S] for genuine multimodal t/h/w positions."""
    B, S, H, hd = x.shape
    half = hd // 2
    freqs = rope_frequencies(hd, theta)                       # [half]
    if positions.ndim == 2:
        positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
    if mrope_sections:
        # M-RoPE: frequency bands are split into (t, h, w) sections, each driven
        # by its own position stream (arXiv:2409.12191).
        sec = np.asarray(mrope_sections)
        assert sec.sum() == half, (mrope_sections, half)
        stream_of_band = np.repeat(np.arange(len(sec)), sec)  # [half] in {0,1,2}
        pos = positions[jnp.asarray(stream_of_band)]          # [half, B, S]
        ang = jnp.einsum("fbs,f->bsf", pos.astype(jnp.float32), freqs)
    else:
        ang = positions[0].astype(jnp.float32)[..., None] * freqs[None, None, :]
    sin = jnp.sin(ang)[:, :, None, :]                         # [B,S,1,half]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    )
    return out.astype(dt)


def sinusoidal_positions(seq_len, d_model, offset=0):
    pos = jnp.arange(seq_len, dtype=jnp.float32) + offset
    half = d_model // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_params(cfg, key, d, f):
    ks = jax.random.split(key, 3)
    pd = param_dtype(cfg)
    if cfg.act == "swiglu":
        return {
            "wg": dense_init(ks[0], (d, f), pd),
            "wi": dense_init(ks[1], (d, f), pd),
            "wo": dense_init(ks[2], (f, d), pd, fan_in=f),
        }
    p = {
        "wi": dense_init(ks[0], (d, f), pd),
        "wo": dense_init(ks[1], (f, d), pd, fan_in=f),
    }
    if cfg.use_bias:
        p["bi"] = jnp.zeros((f,), pd)
        p["bo"] = jnp.zeros((d,), pd)
    return p


def apply_mlp(cfg, p, x):
    dt = x.dtype
    if "wg" in p:
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
        if "bi" in p:
            h = h + p["bi"].astype(dt)
        h = jax.nn.gelu(h)
    out = jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))
    if "bo" in p:
        out = out + p["bo"].astype(dt)
    return out
