from .adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm  # noqa: F401
from .schedule import cosine_schedule  # noqa: F401
from .compress import compress_grads, decompress_grads, ef_init  # noqa: F401
