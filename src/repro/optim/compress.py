"""Error-feedback int8 gradient compression for the cross-pod (DCN) hop.

Distributed-optimization trick (DESIGN.md Sec. 6): in the manual-DP mode the
pod-axis gradient all-reduce is preceded by per-leaf int8 quantization with an
error-feedback accumulator, cutting DCN bytes 4x (f32) / 2x (bf16) at no
asymptotic accuracy cost (the quantization error is re-injected next step).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def ef_init(grads_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    )


def compress_grads(grads: Any, ef: Any):
    """Quantize (grads + ef) to int8 with per-leaf scale; returns
    ((q, scales), new_ef)."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_e = x - q.astype(jnp.float32) * scale
        return (q, scale), new_e

    flat, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef)
    qs, es = zip(*[one(g, e) for g, e in zip(flat, flat_e)])
    return (
        jax.tree_util.tree_unflatten(tdef, [q for q, _ in qs]),
        jax.tree_util.tree_unflatten(tdef, [s for _, s in qs]),
    ), jax.tree_util.tree_unflatten(tdef, list(es))


def decompress_grads(q_tree: Any, scale_tree: Any, dtype=jnp.float32) -> Any:
    return jax.tree_util.tree_map(
        lambda q, s: (q.astype(jnp.float32) * s).astype(dtype), q_tree, scale_tree
    )
