"""AdamW with decoupled weight decay + global-norm clipping (pure pytrees).

Master weights / moments are kept in the params' dtype (f32 by default) and
sharded with the same PartitionSpecs as the params (fully-sharded optimizer
state; DESIGN.md Sec. 6)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(tree))
    )


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), norm


def adamw_update(cfg: AdamWConfig, grads, opt_state, params, lr_scale=1.0):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt_state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * jnp.asarray(lr_scale, jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m.astype(p.dtype), v.astype(p.dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, {"grad_norm": gnorm}
