"""Unified Sampler API: every scheme in the repo behind one interface.

The paper's experiments (Sec. 6) and its production framing (Sec. 5) both need
schemes to be *swappable*: the same stream -> sample -> retrain -> eval loop
is run with R-TBS, T-TBS, B-TBS, uniform reservoir sampling and sliding
windows, differing only in the sampler. This module is that seam
(DESIGN.md Sec. 8):

  * :class:`Sampler`    -- the protocol object: ``init / step / extract``
                           closures with hyperparameters baked in, all
                           jit/scan/vmap-safe.
  * :class:`SampleView` -- the realized sample as fixed-shape arrays:
                           (items pytree, membership mask, size).
  * :func:`make_sampler` / :func:`available_schemes` -- string registry, e.g.
                           ``make_sampler("rtbs", n=300, lam=0.1)``.

Registered schemes (paper reference in parentheses):

  ========  =====================================  ==========================
  name      implementation                         hyperparameters
  ========  =====================================  ==========================
  rtbs      :mod:`repro.core.rtbs` (Alg. 2)        n, lam
  ttbs      :mod:`repro.core.simple` (Alg. 1)      n, lam, batch_size, [cap]
  btbs      :mod:`repro.core.simple` (Alg. 4)      lam, cap
  brs       :mod:`repro.core.simple` (Alg. 5)      n          ("Unif")
  sw        :mod:`repro.core.simple`               n          (sliding window)
  dttbs     :mod:`repro.core.distributed` (S.5.1)  n, lam, batch_size, [cap]
  drtbs     :mod:`repro.core.distributed` (S.5.2)  n, lam, cap_s
  ========  =====================================  ==========================

``dttbs``/``drtbs`` build *per-shard* step closures: their ``step``/``extract``
must run inside ``jax.shard_map`` over the ``data`` mesh axis (see
:data:`repro.core.distributed.AXIS`); the local schemes run anywhere.

Conventions shared by every scheme:

  * ``init(item_proto)`` takes a pytree of arrays / ``ShapeDtypeStruct``s
    describing ONE item and returns the sampler state (a pytree).
  * ``step(key, state, batch_items, bcount)`` consumes one arriving batch --
    a pytree with leading dim ``bcap`` of which the first ``bcount`` rows are
    valid -- and returns the new state. Fixed shapes throughout: safe under
    ``jit``, ``lax.scan`` and ``vmap`` (Monte-Carlo farms).
  * ``extract(key, state)`` realizes the current sample as a
    :class:`SampleView`; for deterministic-membership schemes the key is
    unused. ``view.items`` rows where ``view.mask`` is False are garbage.
    Every scheme guarantees ``view.mask.sum() == view.size``: an item counted
    in the size is materialized in the view (for D-R-TBS the fractional item
    occupies a reserved extra slot).
  * ``size(key, state)`` is the payload-free fast path: the ``view.size``
    that ``extract`` would report for the same key.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from . import distributed, rtbs, simple


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SampleView:
    """A realized sample S_t in fixed-shape form.

    ``items``: pytree, leaves [cap, ...]; ``mask``: bool [cap] membership;
    ``size``: int32 |S_t| (== mask.sum()). Rows with mask False are garbage.
    """

    items: Any
    mask: jax.Array
    size: jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class Sampler:
    """A sampling scheme bound to its hyperparameters.

    Not a pytree: the *state* returned by ``init`` is the pytree that flows
    through ``jit``/``scan``; the Sampler itself is a static bundle of
    closures (close over it freely inside jitted functions). ``eq=False``
    keeps identity hashing, so Samplers work as cache keys -- the manage loop
    memoizes its compiled programs on them.

    ``size(key, state)`` is the cheap size-only realization: it returns
    exactly the ``view.size`` that ``extract`` would report for the same key,
    WITHOUT permuting or gathering any item payloads. The manage loop logs it
    on every tick while ``extract`` runs only on retrain ticks.

    Distributed (per-shard) schemes additionally provide
    ``extract_global(key, state) -> SampleView`` / ``size_global(key, state)``:
    called under ``shard_map``, they assemble the replicated GLOBAL sample
    view (all-gathered shard prefixes + the reserved fractional-item slot) /
    the global size. Local schemes leave them ``None``.
    """

    scheme: str
    init: Callable[[Any], Any]
    step: Callable[[jax.Array, Any, Any, jax.Array], Any]
    extract: Callable[[jax.Array, Any], SampleView]
    size: Callable[[jax.Array, Any], jax.Array]
    hyper: Mapping[str, Any]
    distributed: bool = False
    extract_global: Callable[[jax.Array, Any], SampleView] | None = None
    size_global: Callable[[jax.Array, Any], jax.Array] | None = None

    def __repr__(self) -> str:  # keep hyper readable in logs/tracebacks
        hp = ", ".join(f"{k}={v}" for k, v in self.hyper.items())
        return f"Sampler({self.scheme}, {hp})"


def materialize_view(view: SampleView) -> SampleView:
    """Pack a realized sample's selected rows to the buffer head
    (:func:`repro.core.latent.compact_items`, i.e. the reservoir_compact
    kernel: Pallas on TPU, jnp oracle elsewhere), so downstream consumers see
    a dense ``[0, size)`` prefix instead of a scattered membership mask.

    A no-op in effect for the local schemes (their masks are already
    prefixes); the distributed global views (all-gathered shard prefixes +
    the reserved fractional-item slot) are genuinely block-sparse and this is
    where the kernel earns its keep. Mask-weighted model fits are
    permutation-invariant, so fitting on the materialized view is equivalent
    -- and cheaper for gather-heavy adapters. ``mask.sum() == size`` is
    preserved.
    """
    from . import latent as lt

    items = lt.compact_items(view.items, view.mask)
    cap = view.mask.shape[0]
    mask = jnp.arange(cap) < view.size
    return SampleView(items=items, mask=mask, size=view.size)


_REGISTRY: dict[str, Callable[..., Sampler]] = {}


def register(name: str):
    """Decorator: register a ``**hyper -> Sampler`` builder under ``name``."""

    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def available_schemes() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_sampler(scheme: str, **hyper) -> Sampler:
    """Construct a registered scheme, e.g. ``make_sampler("rtbs", n=300, lam=0.1)``."""
    try:
        builder = _REGISTRY[scheme]
    except KeyError:
        raise ValueError(
            f"unknown sampling scheme {scheme!r}; available: {available_schemes()}"
        ) from None
    return builder(**hyper)


def _ttbs_rates(n: int, lam: float, batch_size: float) -> tuple[float, float]:
    """Alg. 1 parameterization: p = e^{-lam}; q = n(1-p)/b (must be <= 1)."""
    p = math.exp(-lam)
    q = n * (1.0 - p) / batch_size
    if not 0.0 < q <= 1.0:
        raise ValueError(
            f"T-TBS needs q = n(1-e^-lam)/b in (0, 1]; got q={q:.4f} "
            f"(n={n}, lam={lam}, batch_size={batch_size})"
        )
    return p, q


def _buffer_extract(key: jax.Array, state: simple.BufferState) -> SampleView:
    del key  # membership is deterministic: the sample IS the buffer
    mask, size = simple.realize_all(state)
    return SampleView(items=state.items, mask=mask, size=size)


def _buffer_size(key: jax.Array, state: simple.BufferState) -> jax.Array:
    del key  # deterministic membership
    return state.count


# ---------------------------------------------------------------------------
# local schemes
# ---------------------------------------------------------------------------
@register("rtbs")
def _make_rtbs(*, n: int, lam: float) -> Sampler:
    """R-TBS (paper Alg. 2): bounded size + exact time bias at any rate."""

    def step(key, state, batch_items, bcount):
        return rtbs.step(key, state, batch_items, bcount, n=n, lam=lam)

    def extract(key, state):
        mask, size = rtbs.realize(key, state)
        return SampleView(items=state.lat.items, mask=mask, size=size)

    def size(key, state):
        # the size-only path of lt.realize: same key => same partial draw
        from . import latent as lt

        k, take, _ = lt.partial_draw(key, state.lat.weight)
        return k + take.astype(jnp.int32)

    return Sampler(
        scheme="rtbs",
        init=lambda proto: rtbs.init(proto, n),
        step=step,
        extract=extract,
        size=size,
        hyper={"n": n, "lam": lam},
    )


@register("ttbs")
def _make_ttbs(*, n: int, lam: float, batch_size: float, cap: int | None = None) -> Sampler:
    """T-TBS (paper Alg. 1): exact eq. (1), size controlled only in mean."""
    p, q = _ttbs_rates(n, lam, batch_size)
    cap = 4 * n if cap is None else cap

    def step(key, state, batch_items, bcount):
        return simple.ttbs_step(
            key, state, batch_items, bcount, p=jnp.float32(p), q=jnp.float32(q)
        )

    return Sampler(
        scheme="ttbs",
        init=lambda proto: simple.init(proto, cap),
        step=step,
        extract=_buffer_extract,
        size=_buffer_size,
        hyper={"n": n, "lam": lam, "batch_size": batch_size, "cap": cap,
               "p": p, "q": q},
    )


@register("btbs")
def _make_btbs(*, lam: float, cap: int) -> Sampler:
    """B-TBS (paper Alg. 4): Bernoulli TBS -- T-TBS with q = 1."""
    p = math.exp(-lam)

    def step(key, state, batch_items, bcount):
        return simple.btbs_step(key, state, batch_items, bcount, p=jnp.float32(p))

    return Sampler(
        scheme="btbs",
        init=lambda proto: simple.init(proto, cap),
        step=step,
        extract=_buffer_extract,
        size=_buffer_size,
        hyper={"lam": lam, "cap": cap, "p": p},
    )


@register("brs")
def _make_brs(*, n: int) -> Sampler:
    """B-RS (paper Alg. 5): batched uniform reservoir -- the "Unif" baseline."""

    def step(key, state, batch_items, bcount):
        return simple.brs_step(key, state, batch_items, bcount, n=n)

    return Sampler(
        scheme="brs",
        init=lambda proto: simple.init(proto, n),
        step=step,
        extract=_buffer_extract,
        size=_buffer_size,
        hyper={"n": n},
    )


@register("sw")
def _make_sw(*, n: int) -> Sampler:
    """SW: sliding window over the last n items (paper baseline)."""

    def step(key, state, batch_items, bcount):
        return simple.sw_step(key, state, batch_items, bcount, n=n)

    return Sampler(
        scheme="sw",
        init=lambda proto: simple.init(proto, n),
        step=step,
        extract=_buffer_extract,
        size=_buffer_size,
        hyper={"n": n},
    )


# ---------------------------------------------------------------------------
# distributed schemes (per-shard closures; call under jax.shard_map)
# ---------------------------------------------------------------------------
@register("dttbs")
def _make_dttbs(*, n: int, lam: float, batch_size: float, cap: int | None = None) -> Sampler:
    """D-T-TBS (paper Sec. 5.1): embarrassingly parallel per-shard T-TBS.

    ``n``/``batch_size`` are PER-SHARD targets; ``step`` folds the shard index
    into the key, so passing the same key on every shard is correct.
    """
    p, q = _ttbs_rates(n, lam, batch_size)
    cap = 4 * n if cap is None else cap

    def step(key, state, batch_items, bcount):
        return distributed.dttbs_shard_step(
            key, state, batch_items, bcount, p=jnp.float32(p), q=jnp.float32(q)
        )

    def extract_global(key, state):
        del key  # deterministic membership
        items, mask, size = distributed.buffer_realize_global(state)
        # shard prefixes are block-sparse in the gathered view: compact them
        # to a dense [0, size) prefix (reservoir_compact kernel)
        return materialize_view(SampleView(items=items, mask=mask, size=size))

    def size_global(key, state):
        del key
        return jax.lax.psum(state.count, distributed.AXIS)

    return Sampler(
        scheme="dttbs",
        init=lambda proto: simple.init(proto, cap),
        step=step,
        extract=_buffer_extract,
        size=_buffer_size,
        hyper={"n": n, "lam": lam, "batch_size": batch_size, "cap": cap,
               "p": p, "q": q},
        distributed=True,
        extract_global=extract_global,
        size_global=size_global,
    )


@register("drtbs")
def _make_drtbs(*, n: int, lam: float, cap_s: int) -> Sampler:
    """D-R-TBS (paper Sec. 5.2-5.3): co-partitioned reservoir, distributed
    decisions. ``n`` is the GLOBAL bound, ``cap_s`` the per-shard capacity.

    ``extract`` returns this shard's slice of the realized sample with item
    leaves [cap_s + 1, ...]: the shard's full-item buffer plus ONE reserved
    slot (index ``cap_s``) holding the replicated partial payload. The partial
    is realized w.p. frac(C) on shard 0 only (mirroring
    :func:`repro.core.distributed.drtbs_realize_shard`), and whenever it is
    counted in ``size`` its payload is selected by ``mask`` -- so
    ``mask.sum() == size`` holds per shard and globally. ``extract_global``
    assembles the whole-mesh view the sharded manage loop fits models on.
    """

    def step(key, state, batch_items, bcount):
        return distributed.drtbs_shard_step(
            key, state, batch_items, bcount, n=n, lam=lam
        )

    def extract(key, state):
        mask, size, take_partial = distributed.drtbs_realize_shard(key, state)
        items = jax.tree_util.tree_map(
            lambda a, p: jnp.concatenate([a, p[None]], axis=0),
            state.items,
            state.partial_item,
        )
        mask = jnp.concatenate([mask, take_partial[None]])
        return SampleView(items=items, mask=mask, size=size)

    def size(key, state):
        _, size, _ = distributed.drtbs_realize_shard(key, state)
        return size

    def extract_global(key, state):
        items, mask, size = distributed.drtbs_realize_global(key, state)
        # the gathered view interleaves per-shard valid prefixes with garbage
        # tails (+ the reserved fractional slot): compact to a dense prefix
        return materialize_view(SampleView(items=items, mask=mask, size=size))

    return Sampler(
        scheme="drtbs",
        init=lambda proto: distributed.init_shard(proto, cap_s),
        step=step,
        extract=extract,
        size=size,
        hyper={"n": n, "lam": lam, "cap_s": cap_s},
        distributed=True,
        extract_global=extract_global,
        size_global=distributed.drtbs_global_size,
    )
