"""Unified Sampler API: every scheme in the repo behind one interface.

The paper's experiments (Sec. 6) and its production framing (Sec. 5) both need
schemes to be *swappable*: the same stream -> sample -> retrain -> eval loop
is run with R-TBS, T-TBS, B-TBS, uniform reservoir sampling and sliding
windows, differing only in the sampler. This module is that seam
(DESIGN.md Sec. 8):

  * :class:`Sampler`    -- the protocol object: ``init / step / extract``
                           closures with hyperparameters baked in, all
                           jit/scan/vmap-safe.
  * :class:`SampleView` -- the realized sample as fixed-shape arrays:
                           (items pytree, membership mask, size).
  * :func:`make_sampler` / :func:`available_schemes` -- string registry, e.g.
                           ``make_sampler("rtbs", n=300, lam=0.1)``.

Registered schemes (paper reference in parentheses):

  ========  =====================================  ==========================
  name      implementation                         hyperparameters
  ========  =====================================  ==========================
  rtbs      :mod:`repro.core.rtbs` (Alg. 2)        n, lam|decay
  ttbs      :mod:`repro.core.simple` (Alg. 1)      n, lam|decay, batch_size, [cap]
  btbs      :mod:`repro.core.simple` (Alg. 4)      lam|decay, cap
  brs       :mod:`repro.core.simple` (Alg. 5)      n          ("Unif")
  sw        :mod:`repro.core.simple`               n          (sliding window)
  dttbs     :mod:`repro.core.distributed` (S.5.1)  n, lam|decay, batch_size, [cap]
  drtbs     :mod:`repro.core.distributed` (S.5.2)  n, lam|decay, cap_s
  ========  =====================================  ==========================

``dttbs``/``drtbs`` build *per-shard* step closures: their ``step``/``extract``
must run inside ``jax.shard_map`` over the ``data`` mesh axis (see
:data:`repro.core.distributed.AXIS`); the local schemes run anywhere.

Decay (DESIGN.md Sec. 12): every time-biased scheme accepts EITHER a scalar
``lam`` (sugar for ``repro.decay.exponential(lam)``, bit-identical -- the
sugar literally constructs that schedule) OR ``decay=<DecaySchedule>`` for
arbitrary per-tick multiplicative decay (polynomial power-law, piecewise,
callable).  Schedules with a constant factor add NO state; time-varying
schedules carry their bookkeeping in a :class:`repro.decay.DecayedState`
wrapper around the scheme's own state.  Decay-capable schemes additionally
expose ``step_decayed(key, state, batch, bcount, d)`` -- the step with the
tick's factor supplied from outside -- which is how the closed-loop adaptive
controller (:mod:`repro.decay.adaptive`, threaded by
``repro.manage.make_run_loop(..., controller=...)``) drives them.

Conventions shared by every scheme:

  * ``init(item_proto)`` takes a pytree of arrays / ``ShapeDtypeStruct``s
    describing ONE item and returns the sampler state (a pytree).
  * ``step(key, state, batch_items, bcount)`` consumes one arriving batch --
    a pytree with leading dim ``bcap`` of which the first ``bcount`` rows are
    valid -- and returns the new state. Fixed shapes throughout: safe under
    ``jit``, ``lax.scan`` and ``vmap`` (Monte-Carlo farms).
  * ``extract(key, state)`` realizes the current sample as a
    :class:`SampleView`; for deterministic-membership schemes the key is
    unused. ``view.items`` rows where ``view.mask`` is False are garbage.
    Every scheme guarantees ``view.mask.sum() == view.size``: an item counted
    in the size is materialized in the view (for D-R-TBS the fractional item
    occupies a reserved extra slot).
  * ``size(key, state)`` is the payload-free fast path: the ``view.size``
    that ``extract`` would report for the same key.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.decay import DecayedState, DecaySchedule
from repro.decay import resolve as _resolve_schedule

from . import distributed, rtbs, simple


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SampleView:
    """A realized sample S_t in fixed-shape form.

    ``items``: pytree, leaves [cap, ...]; ``mask``: bool [cap] membership;
    ``size``: int32 |S_t| (== mask.sum()). Rows with mask False are garbage.
    """

    items: Any
    mask: jax.Array
    size: jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class Sampler:
    """A sampling scheme bound to its hyperparameters.

    Not a pytree: the *state* returned by ``init`` is the pytree that flows
    through ``jit``/``scan``; the Sampler itself is a static bundle of
    closures (close over it freely inside jitted functions). ``eq=False``
    keeps identity hashing, so Samplers work as cache keys -- the manage loop
    memoizes its compiled programs on them.

    ``size(key, state)`` is the cheap size-only realization: it returns
    exactly the ``view.size`` that ``extract`` would report for the same key,
    WITHOUT permuting or gathering any item payloads. The manage loop logs it
    on every tick while ``extract`` runs only on retrain ticks.

    ``step_decayed(key, state, batch, bcount, d)`` -- present on every
    time-biased scheme, ``None`` on the decay-free baselines (brs/sw) -- is
    ``step`` with the tick's multiplicative decay factor ``d`` supplied as an
    operand (replicated and possibly traced). The manage loop's closed-loop
    controller drives schemes exclusively through it; when the sampler was
    built with a time-varying schedule, the external ``d`` overrides the
    schedule's factor for that tick (the schedule state still advances).

    Distributed (per-shard) schemes additionally provide
    ``extract_global(key, state) -> SampleView`` / ``size_global(key, state)``:
    called under ``shard_map``, they assemble the replicated GLOBAL sample
    view (all-gathered shard prefixes + the reserved fractional-item slot) /
    the global size. Local schemes leave them ``None``.
    """

    scheme: str
    init: Callable[[Any], Any]
    step: Callable[[jax.Array, Any, Any, jax.Array], Any]
    extract: Callable[[jax.Array, Any], SampleView]
    size: Callable[[jax.Array, Any], jax.Array]
    hyper: Mapping[str, Any]
    distributed: bool = False
    extract_global: Callable[[jax.Array, Any], SampleView] | None = None
    size_global: Callable[[jax.Array, Any], jax.Array] | None = None
    step_decayed: Callable[
        [jax.Array, Any, Any, jax.Array, jax.Array], Any
    ] | None = None

    def __repr__(self) -> str:  # keep hyper readable in logs/tracebacks
        hp = ", ".join(f"{k}={v}" for k, v in self.hyper.items())
        return f"Sampler({self.scheme}, {hp})"


def materialize_view(view: SampleView) -> SampleView:
    """Pack a realized sample's selected rows to the buffer head
    (:func:`repro.core.latent.compact_items`, i.e. the reservoir_compact
    kernel: Pallas on TPU, jnp oracle elsewhere), so downstream consumers see
    a dense ``[0, size)`` prefix instead of a scattered membership mask.

    A no-op in effect for the local schemes (their masks are already
    prefixes); the distributed global views (all-gathered shard prefixes +
    the reserved fractional-item slot) are genuinely block-sparse and this is
    where the kernel earns its keep. Mask-weighted model fits are
    permutation-invariant, so fitting on the materialized view is equivalent
    -- and cheaper for gather-heavy adapters. ``mask.sum() == size`` is
    preserved.
    """
    from . import latent as lt

    items = lt.compact_items(view.items, view.mask)
    cap = view.mask.shape[0]
    mask = jnp.arange(cap) < view.size
    return SampleView(items=items, mask=mask, size=view.size)


_REGISTRY: dict[str, Callable[..., Sampler]] = {}


def register(name: str):
    """Decorator: register a ``**hyper -> Sampler`` builder under ``name``."""

    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def available_schemes() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_sampler(scheme: str, **hyper) -> Sampler:
    """Construct a registered scheme, e.g. ``make_sampler("rtbs", n=300,
    lam=0.1)`` or ``make_sampler("rtbs", n=300,
    decay=repro.decay.polynomial(0.8))``."""
    try:
        builder = _REGISTRY[scheme]
    except KeyError:
        raise ValueError(
            f"unknown sampling scheme {scheme!r}; available: {available_schemes()}"
        ) from None
    return builder(**hyper)


def _thread_schedule(sched: DecaySchedule, *, init, step_d, extract, size,
                     extract_global=None, size_global=None) -> dict:
    """Wire a :class:`~repro.decay.DecaySchedule` into a scheme's
    decay-parametric closures (DESIGN.md Sec. 12).

    ``step_d(key, state, batch, bcount, d)`` is the scheme's step with the
    tick's multiplicative factor ``d`` as an operand.  Constant schedules
    (``static_rate`` set -- the exponential/``lam`` sugar) bake the factor in
    and keep the scheme's bare state, so traces and pytree structure are
    identical to the historical scalar-``lam`` samplers.  Time-varying
    schedules wrap the state in :class:`~repro.decay.DecayedState` and pull
    ``d`` from the schedule per tick.  Either way the returned
    ``step_decayed`` operates on the SAME state structure as ``step`` -- the
    contract the manage-loop controller relies on.
    """
    if sched.static_rate is not None:
        d0 = jnp.float32(sched.static_rate)

        def step(key, state, batch_items, bcount):
            return step_d(key, state, batch_items, bcount, d0)

        return dict(init=init, step=step, extract=extract, size=size,
                    step_decayed=step_d, extract_global=extract_global,
                    size_global=size_global)

    def init_w(proto):
        return DecayedState(dstate=sched.init(), inner=init(proto))

    def step_w(key, state, batch_items, bcount):
        d, dstate = sched.tick(state.dstate)
        return DecayedState(
            dstate=dstate,
            inner=step_d(key, state.inner, batch_items, bcount, d),
        )

    def step_decayed(key, state, batch_items, bcount, d):
        # external d (controller) overrides the schedule's factor for this
        # tick; the schedule state still advances so the two stay composable
        return DecayedState(
            dstate=sched.step(state.dstate),
            inner=step_d(key, state.inner, batch_items, bcount, d),
        )

    def unwrap(fn):
        if fn is None:
            return None
        return lambda key, state: fn(key, state.inner)

    return dict(init=init_w, step=step_w, extract=unwrap(extract),
                size=unwrap(size), step_decayed=step_decayed,
                extract_global=unwrap(extract_global),
                size_global=unwrap(size_global))


def _decay_hyper(sched: DecaySchedule, lam) -> dict:
    """hyper entries recording the decay choice (keep the historical ``lam``
    key for the sugar form)."""
    h = {"decay": sched}
    if lam is not None:
        h["lam"] = lam
    return h


def _ttbs_rates(n: int, p: float, batch_size: float) -> tuple[float, float]:
    """Alg. 1 parameterization from the retention prob p = e^{-lam}:
    q = n(1-p)/b (must be <= 1)."""
    q = n * (1.0 - p) / batch_size
    if not 0.0 < q <= 1.0:
        raise ValueError(
            f"T-TBS needs q = n(1-e^-lam)/b in (0, 1]; got q={q:.4f} "
            f"(n={n}, lam={-math.log(p):.4f}, batch_size={batch_size})"
        )
    return p, q


def _ttbs_step_d(n: int, batch_size: float):
    """Alg. 1 with the decay factor as an operand: p_t = d_t and
    q_t = n (1 - p_t) / b, clipped into [0, 1] -- time-varying schedules can
    transiently demand q > 1 (arrival rate can't sustain the target size);
    the clip under-fills instead of failing, mirroring Thm 3.1's
    probabilistic size control."""

    def step_d(key, state, batch_items, bcount, d):
        d = jnp.asarray(d, jnp.float32)
        q = jnp.clip(n * (1.0 - d) / jnp.float32(batch_size), 0.0, 1.0)
        return simple.ttbs_step(key, state, batch_items, bcount, p=d, q=q)

    return step_d


def _buffer_extract(key: jax.Array, state: simple.BufferState) -> SampleView:
    del key  # membership is deterministic: the sample IS the buffer
    mask, size = simple.realize_all(state)
    return SampleView(items=state.items, mask=mask, size=size)


def _buffer_size(key: jax.Array, state: simple.BufferState) -> jax.Array:
    del key  # deterministic membership
    return state.count


# ---------------------------------------------------------------------------
# local schemes
# ---------------------------------------------------------------------------
@register("rtbs")
def _make_rtbs(*, n: int, lam: float | None = None,
               decay: DecaySchedule | None = None) -> Sampler:
    """R-TBS (paper Alg. 2): bounded size + exact time bias at any rate."""
    sched = _resolve_schedule(lam, decay)

    def step_d(key, state, batch_items, bcount, d):
        return rtbs.step(key, state, batch_items, bcount, n=n, decay=d)

    def extract(key, state):
        mask, size = rtbs.realize(key, state)
        return SampleView(items=state.lat.items, mask=mask, size=size)

    def size(key, state):
        # the size-only path of lt.realize: same key => same partial draw
        from . import latent as lt

        k, take, _ = lt.partial_draw(key, state.lat.weight)
        return k + take.astype(jnp.int32)

    return Sampler(
        scheme="rtbs",
        hyper={"n": n, **_decay_hyper(sched, lam)},
        **_thread_schedule(
            sched,
            init=lambda proto: rtbs.init(proto, n),
            step_d=step_d,
            extract=extract,
            size=size,
        ),
    )


@register("ttbs")
def _make_ttbs(*, n: int, lam: float | None = None, batch_size: float,
               cap: int | None = None,
               decay: DecaySchedule | None = None) -> Sampler:
    """T-TBS (paper Alg. 1): exact eq. (1), size controlled only in mean."""
    sched = _resolve_schedule(lam, decay)
    cap = 4 * n if cap is None else cap
    hyper = {"n": n, **_decay_hyper(sched, lam), "batch_size": batch_size,
             "cap": cap}
    fields = _thread_schedule(
        sched,
        init=lambda proto: simple.init(proto, cap),
        step_d=_ttbs_step_d(n, batch_size),
        extract=_buffer_extract,
        size=_buffer_size,
    )
    if sched.static_rate is not None:
        # eager Alg.-1 validation for the time-invariant case (the q > 1
        # failure mode should fail fast, not silently under-fill), and the
        # constant-rate step applies EXACTLY these f64-derived p/q -- the
        # recorded hyper must be the rates the step uses, not a per-tick
        # f32 recomputation one ulp away
        p, q = _ttbs_rates(n, sched.static_rate, batch_size)
        hyper.update(p=p, q=q)
        pq = (jnp.float32(p), jnp.float32(q))

        def step(key, state, batch_items, bcount):
            return simple.ttbs_step(key, state, batch_items, bcount,
                                    p=pq[0], q=pq[1])

        fields["step"] = step

    return Sampler(scheme="ttbs", hyper=hyper, **fields)


@register("btbs")
def _make_btbs(*, lam: float | None = None, cap: int,
               decay: DecaySchedule | None = None) -> Sampler:
    """B-TBS (paper Alg. 4): Bernoulli TBS -- T-TBS with q = 1."""
    sched = _resolve_schedule(lam, decay)

    def step_d(key, state, batch_items, bcount, d):
        return simple.btbs_step(key, state, batch_items, bcount,
                                p=jnp.asarray(d, jnp.float32))

    return Sampler(
        scheme="btbs",
        hyper={**_decay_hyper(sched, lam), "cap": cap},
        **_thread_schedule(
            sched,
            init=lambda proto: simple.init(proto, cap),
            step_d=step_d,
            extract=_buffer_extract,
            size=_buffer_size,
        ),
    )


@register("brs")
def _make_brs(*, n: int) -> Sampler:
    """B-RS (paper Alg. 5): batched uniform reservoir -- the "Unif" baseline."""

    def step(key, state, batch_items, bcount):
        return simple.brs_step(key, state, batch_items, bcount, n=n)

    return Sampler(
        scheme="brs",
        init=lambda proto: simple.init(proto, n),
        step=step,
        extract=_buffer_extract,
        size=_buffer_size,
        hyper={"n": n},
    )


@register("sw")
def _make_sw(*, n: int) -> Sampler:
    """SW: sliding window over the last n items (paper baseline)."""

    def step(key, state, batch_items, bcount):
        return simple.sw_step(key, state, batch_items, bcount, n=n)

    return Sampler(
        scheme="sw",
        init=lambda proto: simple.init(proto, n),
        step=step,
        extract=_buffer_extract,
        size=_buffer_size,
        hyper={"n": n},
    )


# ---------------------------------------------------------------------------
# distributed schemes (per-shard closures; call under jax.shard_map)
# ---------------------------------------------------------------------------
@register("dttbs")
def _make_dttbs(*, n: int, lam: float | None = None, batch_size: float,
                cap: int | None = None,
                decay: DecaySchedule | None = None) -> Sampler:
    """D-T-TBS (paper Sec. 5.1): embarrassingly parallel per-shard T-TBS.

    ``n``/``batch_size`` are PER-SHARD targets; ``step`` folds the shard index
    into the key, so passing the same key on every shard is correct.
    """
    sched = _resolve_schedule(lam, decay)
    cap = 4 * n if cap is None else cap
    hyper = {"n": n, **_decay_hyper(sched, lam), "batch_size": batch_size,
             "cap": cap}
    local_step_d = _ttbs_step_d(n, batch_size)

    def step_d(key, state, batch_items, bcount, d):
        me = jax.lax.axis_index(distributed.AXIS)
        return local_step_d(jax.random.fold_in(key, me), state, batch_items,
                            bcount, d)

    def extract_global(key, state):
        del key  # deterministic membership
        items, mask, size = distributed.buffer_realize_global(state)
        # shard prefixes are block-sparse in the gathered view: compact them
        # to a dense [0, size) prefix (reservoir_compact kernel)
        return materialize_view(SampleView(items=items, mask=mask, size=size))

    def size_global(key, state):
        del key
        return jax.lax.psum(state.count, distributed.AXIS)

    fields = _thread_schedule(
        sched,
        init=lambda proto: simple.init(proto, cap),
        step_d=step_d,
        extract=_buffer_extract,
        size=_buffer_size,
        extract_global=extract_global,
        size_global=size_global,
    )
    if sched.static_rate is not None:
        # as for ttbs: validate eagerly and apply the recorded f64-derived
        # p/q verbatim on the constant-rate step
        p, q = _ttbs_rates(n, sched.static_rate, batch_size)
        hyper.update(p=p, q=q)

        def step(key, state, batch_items, bcount):
            return distributed.dttbs_shard_step(
                key, state, batch_items, bcount,
                p=jnp.float32(p), q=jnp.float32(q),
            )

        fields["step"] = step

    return Sampler(scheme="dttbs", hyper=hyper, distributed=True, **fields)


@register("drtbs")
def _make_drtbs(*, n: int, lam: float | None = None, cap_s: int,
                decay: DecaySchedule | None = None) -> Sampler:
    """D-R-TBS (paper Sec. 5.2-5.3): co-partitioned reservoir, distributed
    decisions. ``n`` is the GLOBAL bound, ``cap_s`` the per-shard capacity.

    ``extract`` returns this shard's slice of the realized sample with item
    leaves [cap_s + 1, ...]: the shard's full-item buffer plus ONE reserved
    slot (index ``cap_s``) holding the replicated partial payload. The partial
    is realized w.p. frac(C) on shard 0 only (mirroring
    :func:`repro.core.distributed.drtbs_realize_shard`), and whenever it is
    counted in ``size`` its payload is selected by ``mask`` -- so
    ``mask.sum() == size`` holds per shard and globally. ``extract_global``
    assembles the whole-mesh view the sharded manage loop fits models on.
    """
    sched = _resolve_schedule(lam, decay)

    def step_d(key, state, batch_items, bcount, d):
        return distributed.drtbs_shard_step(
            key, state, batch_items, bcount, n=n, decay=d
        )

    def extract(key, state):
        mask, size, take_partial = distributed.drtbs_realize_shard(key, state)
        items = jax.tree_util.tree_map(
            lambda a, p: jnp.concatenate([a, p[None]], axis=0),
            state.items,
            state.partial_item,
        )
        mask = jnp.concatenate([mask, take_partial[None]])
        return SampleView(items=items, mask=mask, size=size)

    def size(key, state):
        _, size, _ = distributed.drtbs_realize_shard(key, state)
        return size

    def extract_global(key, state):
        items, mask, size = distributed.drtbs_realize_global(key, state)
        # the gathered view interleaves per-shard valid prefixes with garbage
        # tails (+ the reserved fractional slot): compact to a dense prefix
        return materialize_view(SampleView(items=items, mask=mask, size=size))

    return Sampler(
        scheme="drtbs",
        hyper={"n": n, **_decay_hyper(sched, lam), "cap_s": cap_s},
        distributed=True,
        **_thread_schedule(
            sched,
            init=lambda proto: distributed.init_shard(proto, cap_s),
            step_d=step_d,
            extract=extract,
            size=size,
            extract_global=extract_global,
            size_global=distributed.drtbs_global_size,
        ),
    )
