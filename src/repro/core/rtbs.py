"""R-TBS: Reservoir-based Time-Biased Sampling (paper Algorithm 2).

The first sampling scheme that simultaneously (i) enforces the exponential
relative-inclusion criterion (paper eq. (1)) at all times, (ii) guarantees
|S_t| <= n, and (iii) tolerates unknown / arbitrarily varying arrival rates.
Invariant maintained (Theorem 4.2):  Pr[i in S_t] = (C_t / W_t) * w_t(i).

Fixed-shape JAX formulation. State:
  * ``lat`` -- the latent fractional sample (capacity n+1 slots)
  * ``total_weight`` -- W_t = sum_j B_j e^{-lambda (t-j)}

Each :func:`step` consumes one arriving batch (valid prefix of a fixed-capacity
buffer) and is fully jit/scan-safe; `vmap` over steps gives Monte-Carlo farms for
the statistical tests.

Two step implementations share the scalar bookkeeping (identical C_t/W_t
trajectories, asserted in tests):

  * :func:`step` -- the FUSED hot path (DESIGN.md Sec. 11). Every branch of
    Alg. 2 (decay-downsample, batch insert, overshoot-downsample, victim
    replacement) is computed as a slot-index map over the two sources
    (reservoir, batch) and composed in O(cap) integer ops with argsort-free
    randomness (:func:`repro.core.rng.prefix_permutation_fast`); the payload
    then moves in ONE two-source pass via
    :func:`repro.kernels.tbs_step.ops.tbs_step_apply` (Pallas kernel on TPU,
    jnp oracle elsewhere).
  * :func:`step_ref` -- the pre-fused reference: per-stage buffer rewrites
    (downsample gather, widened-buffer insert, second gather) with exact
    argsort permutations. Kept for parity tests and as the benchmark
    baseline (benchmarks/manage_loop.py, BENCH_sampler_step.json).

Step structure mirrors Alg. 2 exactly:
  unsaturated (W < n):  decay+downsample, accept all arrivals, then downsample
                        to n on overshoot (lines 5-12)
  saturated  (W >= n):  decay W; still saturated -> replace StochRound(B*n/W)
                        victims with batch items (lines 16-17); undershoot ->
                        downsample to W - B and accept all arrivals (lines 19-20)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.tbs_step import ops as tbs_ops
from repro.obs.profile import scope as _scope

from . import latent as lt
from . import rng


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RTBSState:
    lat: lt.Latent
    total_weight: jax.Array  # float32 scalar, W_t

    @property
    def sample_weight(self) -> jax.Array:  # C_t = min(n, W_t) implicitly == lat.weight
        return self.lat.weight


def init(item_proto: Any, n: int) -> RTBSState:
    """Empty R-TBS state with max sample size n (buffer capacity n+1)."""
    return RTBSState(
        lat=lt.make_empty(item_proto, n + 1), total_weight=jnp.float32(0.0)
    )


# ---------------------------------------------------------------------------
# the fused step: one composed slot map, one payload pass (DESIGN.md Sec. 11)
# ---------------------------------------------------------------------------
def tick_map(key, nfull, weight, total_weight, bcount, decay, *,
             cap: int, bcap: int, n: int):
    """Compose the whole tick's buffer rewrite into ONE slot map.

    Returns ``(src[cap] int32, new_sample_weight, w_new)`` where ``src``
    values in [0, cap) read the old reservoir and values in [cap, cap + bcap)
    read the arriving batch (slot ``cap + j`` = batch row j). The caller
    applies it in a single two-source payload pass; all the work here is
    O(cap + bcap) integer/scalar ops and at most two swap-or-not PRP
    evaluations -- no argsort, no intermediate payload buffers.

    Scalar-operand form (``nfull``/``weight``/``total_weight``/``bcount``/
    ``decay`` traced, ``cap``/``bcap``/``n`` static) so that
    :mod:`repro.bank` can ``vmap`` it over the touched keys of a keyed batch
    with per-key composed decay factors (DESIGN.md Sec. 13);
    :func:`step` feeds it a single :class:`RTBSState`.
    """
    bf = jnp.asarray(bcount, jnp.float32)
    bcnt = jnp.asarray(bcount, jnp.int32)
    w_prev = total_weight
    C = weight
    k0 = nfull
    was_unsat = w_prev < n
    w_dec = decay * w_prev
    w_new = w_dec + bf                # both Alg. 2 branches decay then add B
    still_sat = (~was_unsat) & (w_new >= n)

    k_ds, k_over, k_m, k_vic, k_pick = jax.random.split(key, 5)
    nf = jnp.float32(n)

    def insert_path():
        """Alg. 2 lines 5-12 / 19-20: (maybe) downsample, accept all arrivals,
        (maybe) downsample the widened virtual buffer back to n."""
        V = cap + bcap
        # stage 1: decay downsample (unsat lines 6-8) or undershoot downsample
        # to W - B (sat lines 19-20)
        t1 = jnp.where(was_unsat, w_dec, w_new - bf)
        apply1 = jnp.where(was_unsat, (w_dec > 0) & (w_dec < C), True)
        # delete-complement fast path: a decay/undershoot trim removes
        # C - t1 ~ (1 - d) C items -- usually far fewer than bcap -- so the
        # map costs O(bcap) instead of a full-domain PRP evaluation (the
        # fill-up-phase hot spot; falls back past bcap deletions at runtime)
        src1 = jnp.where(
            apply1,
            lt.downsample_map(k_ds, cap, k0, C, t1, max_deleted=bcap),
            jnp.arange(cap, dtype=jnp.int32),
        )
        C1 = jnp.where(
            apply1,
            jnp.minimum(t1, C),
            jnp.minimum(C, jnp.maximum(t1, 0.0)),
        )
        k1, _ = lt.floor_frac(C1)

        # stage 2: insert the batch as full items on the widened virtual
        # buffer [0, V): slots [k1, k1+bcnt) <- batch rows, partial relocated
        # to k1+bcnt (lt.insert_full's layout, as a map)
        j = jnp.arange(V, dtype=jnp.int32)
        src1_at = src1[jnp.minimum(j, cap - 1)]
        mid = jnp.where(
            j < k1,
            src1_at,
            jnp.where(
                j < k1 + bcnt,
                cap + (j - k1),
                jnp.where(j == k1 + bcnt, src1[jnp.minimum(k1, cap - 1)], j),
            ),
        )
        C2 = C1 + bf

        # stage 3: overshoot downsample to n (unsat lines 11-12 only)
        overshoot = was_unsat & (C2 > nf)
        # an overshoot trims C2 - n <= B <= bcap items: always the fast map
        src2 = jax.lax.cond(
            overshoot,
            lambda: lt.downsample_map(k_over, V, k1 + bcnt, C2, nf,
                                      max_deleted=bcap),
            lambda: jnp.arange(V, dtype=jnp.int32),
        )
        src = mid[src2[:cap]]          # compose: one gather of int32 maps
        C3 = jnp.where(overshoot, nf, C2)
        return src, C3

    def replace_path():
        """Alg. 2 lines 16-17: replace m = StochRound(B*n/W) victims."""
        m = rng.stochastic_round(k_m, bf * n / jnp.maximum(w_new, 1e-30))
        victims = rng.prefix_permutation_fast(k_vic, cap, k0, k=bcap)
        picks = rng.prefix_permutation_fast(k_pick, bcap, bcnt, k=bcap)
        i = jnp.arange(bcap, dtype=jnp.int32)
        dest = jnp.where(i < m, victims, cap)          # cap => dropped
        src = (
            jnp.arange(cap, dtype=jnp.int32)
            .at[dest]
            .set(cap + picks, mode="drop")
        )
        return src, nf

    src, C3 = jax.lax.cond(still_sat, replace_path, insert_path)
    return src, C3, w_new


def _tick_map(key, state: RTBSState, bcount, bcap: int, *, n: int, decay):
    """:func:`tick_map` on an :class:`RTBSState` (the fused step's view)."""
    return tick_map(
        key, state.lat.nfull, state.lat.weight, state.total_weight, bcount,
        decay, cap=state.lat.cap, bcap=bcap, n=n,
    )


def _resolve_decay(lam, decay) -> jax.Array:
    """The per-tick multiplicative decay factor d_t from either a rate
    (``lam`` -> e^{-lam}) or the factor itself (``decay``, as produced by a
    :mod:`repro.decay` schedule / controller). Exactly one must be given;
    both may be traced scalars (DESIGN.md Sec. 12)."""
    if (lam is None) == (decay is None):
        raise ValueError(
            f"pass exactly one of lam= or decay=; got lam={lam!r}, "
            f"decay={decay!r}"
        )
    if decay is None:
        return jnp.exp(-jnp.asarray(lam, jnp.float32))
    return jnp.asarray(decay, jnp.float32)


@functools.partial(jax.jit, static_argnames=("n", "impl"))
def step(
    key: jax.Array,
    state: RTBSState,
    batch_items: Any,
    bcount: jax.Array,
    *,
    n: int,
    lam: float | jax.Array | None = None,
    decay: float | jax.Array | None = None,
    impl: str | None = None,
) -> RTBSState:
    """Advance R-TBS by one batch arrival (paper Algorithm 2), fused.

    ``batch_items``: pytree, leaves [bcap, ...]; valid prefix length ``bcount``.
    ``lam`` may be a traced scalar; elapsed time between batches is 1 (use
    lam * dt for irregular arrivals, per paper Sec. 2). ``decay`` gives the
    per-tick multiplicative factor d_t directly instead (the
    :mod:`repro.decay` schedules and the adaptive controller feed this form;
    pass exactly one of the two). ``impl`` routes the payload pass (None =
    auto: Pallas kernel on TPU, jnp oracle elsewhere; see
    :mod:`repro.kernels.tbs_step.ops`).

    Identical C_t/W_t trajectories and sampling distribution as
    :func:`step_ref` (asserted in tests/test_tbs_step.py); the RNG stream
    differs (DESIGN.md Sec. 11).
    """
    decay = _resolve_decay(lam, decay)
    bcount = jnp.asarray(bcount, jnp.int32)
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]

    with _scope("rtbs.tick_map"):
        src, C3, w_new = _tick_map(key, state, bcount, bcap, n=n, decay=decay)
        k3, _ = lt.floor_frac(C3)
    with _scope("rtbs.payload"):
        new_items = tbs_ops.tbs_step_apply(state.lat.items, batch_items, src,
                                           impl=impl)
    return RTBSState(
        lat=lt.Latent(items=new_items, nfull=k3, weight=C3),
        total_weight=w_new,
    )


# ---------------------------------------------------------------------------
# the reference step: per-stage buffer rewrites, exact argsort permutations
# ---------------------------------------------------------------------------
def _unsaturated_path(key, lat, w_prev, batch_items, bcount, n, decay):
    """Paper Alg. 2 lines 5-12 (previously unsaturated: C == W < n)."""
    k_ds, k_over = jax.random.split(key)
    w_dec = decay * w_prev
    # lines 6-8: decay weight, downsample the latent to the decayed weight
    lat = jax.lax.cond(
        (w_dec > 0) & (w_dec < lat.weight),
        lambda: lt.downsample(k_ds, lat, w_dec, exact=True),
        lambda: dataclasses.replace(
            lat, weight=jnp.minimum(lat.weight, jnp.maximum(w_dec, 0.0))
        ),
    )
    # lines 9-10: accept ALL batch items (on a widened temp buffer)
    cap = lat.cap
    wide = lt.Latent(
        items=lt.concat_items(
            lat.items,
            jax.tree_util.tree_map(lambda b: jnp.zeros_like(b), batch_items),
        ),
        nfull=lat.nfull,
        weight=lat.weight,
    )
    wide = lt.insert_full(wide, batch_items, bcount)
    w_new = w_dec + jnp.asarray(bcount, jnp.float32)
    # lines 11-12: overshoot -> downsample to n (sample becomes saturated)
    wide = jax.lax.cond(
        wide.weight > n,
        lambda: lt.downsample(k_over, wide, jnp.float32(n), exact=True),
        lambda: wide,
    )
    out = lt.Latent(
        items=lt.truncate_items(wide.items, cap), nfull=wide.nfull, weight=wide.weight
    )
    return out, w_new


def _saturated_path(key, lat, w_prev, batch_items, bcount, n, decay):
    """Paper Alg. 2 lines 14-20 (previously saturated: C == n <= W)."""
    k_m, k_vic, k_pick, k_ds = jax.random.split(key, 4)
    bcapf = jnp.asarray(bcount, jnp.float32)
    w_new = decay * w_prev + bcapf
    cap = lat.cap
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]

    def still_saturated():
        # lines 16-17: replace m = StochRound(B*n/W) victims with batch items
        m = rng.stochastic_round(k_m, bcapf * n / jnp.maximum(w_new, 1e-30))
        victims = rng.prefix_permutation(k_vic, cap, lat.nfull)
        picks = rng.prefix_permutation(k_pick, bcap, bcount)
        i = jnp.arange(bcap, dtype=jnp.int32)
        dest = jnp.where(i < m, victims[jnp.minimum(i, cap - 1)], cap)  # cap => drop
        payload = lt.gather(batch_items, picks)
        items = jax.tree_util.tree_map(
            lambda a, b: a.at[dest].set(b, mode="drop"), lat.items, payload
        )
        return lt.Latent(items=items, nfull=lat.nfull, weight=jnp.float32(n))

    def undershoot():
        # lines 19-20: downsample to W' = W - B, then accept all batch items
        l2 = lt.downsample(k_ds, lat, w_new - bcapf, exact=True)
        return lt.insert_full(l2, batch_items, bcount)

    out = jax.lax.cond(w_new >= n, still_saturated, undershoot)
    return out, w_new


@functools.partial(jax.jit, static_argnames=("n",))
def step_ref(
    key: jax.Array,
    state: RTBSState,
    batch_items: Any,
    bcount: jax.Array,
    *,
    n: int,
    lam: float | jax.Array | None = None,
    decay: float | jax.Array | None = None,
) -> RTBSState:
    """The pre-fused R-TBS step: per-stage buffer rewrites with exact argsort
    permutations -- 2-4 full sorts + multi-gather slot remaps per tick. Kept
    as the parity oracle and the benchmark baseline; use :func:`step`."""
    decay = _resolve_decay(lam, decay)
    bcount = jnp.asarray(bcount, jnp.int32)
    was_unsat = state.total_weight < n
    lat, w_new = jax.lax.cond(
        was_unsat,
        lambda: _unsaturated_path(
            key, state.lat, state.total_weight, batch_items, bcount, n, decay
        ),
        lambda: _saturated_path(
            key, state.lat, state.total_weight, batch_items, bcount, n, decay
        ),
    )
    return RTBSState(lat=lat, total_weight=w_new)


def realize(key: jax.Array, state: RTBSState) -> tuple[jax.Array, jax.Array]:
    """Draw the actual sample S_t: (mask over the n+1 slots, |S_t|)."""
    return lt.realize(key, state.lat)


def run_stream(
    key: jax.Array,
    state: RTBSState,
    batches: Any,
    bcounts: jax.Array,
    *,
    n: int,
    lam: float,
    impl: str | None = None,
    use_ref: bool = False,
) -> tuple[RTBSState, dict]:
    """Scan ``step`` over a stream of T batches; returns final state + per-step
    trace (sample weight C_t, total weight W_t, realized size E via C).
    ``use_ref`` scans :func:`step_ref` instead (parity tests, benchmarks)."""

    def body(carry, inp):
        st = carry
        items_t, cnt_t, key_t = inp
        if use_ref:
            st = step_ref(key_t, st, items_t, cnt_t, n=n, lam=lam)
        else:
            st = step(key_t, st, items_t, cnt_t, n=n, lam=lam, impl=impl)
        return st, {"C": st.lat.weight, "W": st.total_weight}

    T = bcounts.shape[0]
    keys = jax.random.split(key, T)
    final, trace = jax.lax.scan(body, state, (batches, bcounts, keys))
    return final, trace
