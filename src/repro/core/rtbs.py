"""R-TBS: Reservoir-based Time-Biased Sampling (paper Algorithm 2).

The first sampling scheme that simultaneously (i) enforces the exponential
relative-inclusion criterion (paper eq. (1)) at all times, (ii) guarantees
|S_t| <= n, and (iii) tolerates unknown / arbitrarily varying arrival rates.
Invariant maintained (Theorem 4.2):  Pr[i in S_t] = (C_t / W_t) * w_t(i).

Fixed-shape JAX formulation. State:
  * ``lat`` -- the latent fractional sample (capacity n+1 slots)
  * ``total_weight`` -- W_t = sum_j B_j e^{-lambda (t-j)}

Each :func:`step` consumes one arriving batch (valid prefix of a fixed-capacity
buffer) and is fully jit/scan-safe; `vmap` over steps gives Monte-Carlo farms for
the statistical tests.

Step structure mirrors Alg. 2 exactly:
  unsaturated (W < n):  decay+downsample, accept all arrivals, then downsample
                        to n on overshoot (lines 5-12)
  saturated  (W >= n):  decay W; still saturated -> replace StochRound(B*n/W)
                        victims with batch items (lines 16-17); undershoot ->
                        downsample to W - B and accept all arrivals (lines 19-20)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import latent as lt
from . import rng


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RTBSState:
    lat: lt.Latent
    total_weight: jax.Array  # float32 scalar, W_t

    @property
    def sample_weight(self) -> jax.Array:  # C_t = min(n, W_t) implicitly == lat.weight
        return self.lat.weight


def init(item_proto: Any, n: int) -> RTBSState:
    """Empty R-TBS state with max sample size n (buffer capacity n+1)."""
    return RTBSState(
        lat=lt.make_empty(item_proto, n + 1), total_weight=jnp.float32(0.0)
    )


def _unsaturated_path(key, lat, w_prev, batch_items, bcount, n, decay):
    """Paper Alg. 2 lines 5-12 (previously unsaturated: C == W < n)."""
    k_ds, k_over = jax.random.split(key)
    w_dec = decay * w_prev
    # lines 6-8: decay weight, downsample the latent to the decayed weight
    lat = jax.lax.cond(
        (w_dec > 0) & (w_dec < lat.weight),
        lambda: lt.downsample(k_ds, lat, w_dec),
        lambda: dataclasses.replace(
            lat, weight=jnp.minimum(lat.weight, jnp.maximum(w_dec, 0.0))
        ),
    )
    # lines 9-10: accept ALL batch items (on a widened temp buffer)
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]
    cap = lat.cap
    wide = lt.Latent(
        items=lt.concat_items(
            lat.items,
            jax.tree_util.tree_map(lambda b: jnp.zeros_like(b), batch_items),
        ),
        nfull=lat.nfull,
        weight=lat.weight,
    )
    wide = lt.insert_full(wide, batch_items, bcount)
    w_new = w_dec + jnp.asarray(bcount, jnp.float32)
    # lines 11-12: overshoot -> downsample to n (sample becomes saturated)
    wide = jax.lax.cond(
        wide.weight > n,
        lambda: lt.downsample(k_over, wide, jnp.float32(n)),
        lambda: wide,
    )
    out = lt.Latent(
        items=lt.truncate_items(wide.items, cap), nfull=wide.nfull, weight=wide.weight
    )
    return out, w_new


def _saturated_path(key, lat, w_prev, batch_items, bcount, n, decay):
    """Paper Alg. 2 lines 14-20 (previously saturated: C == n <= W)."""
    k_m, k_vic, k_pick, k_ds = jax.random.split(key, 4)
    bcapf = jnp.asarray(bcount, jnp.float32)
    w_new = decay * w_prev + bcapf
    cap = lat.cap
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]

    def still_saturated():
        # lines 16-17: replace m = StochRound(B*n/W) victims with batch items
        m = rng.stochastic_round(k_m, bcapf * n / jnp.maximum(w_new, 1e-30))
        victims = rng.prefix_permutation(k_vic, cap, lat.nfull)
        picks = rng.prefix_permutation(k_pick, bcap, bcount)
        i = jnp.arange(bcap, dtype=jnp.int32)
        dest = jnp.where(i < m, victims[jnp.minimum(i, cap - 1)], cap)  # cap => drop
        payload = lt.gather(batch_items, picks)
        items = jax.tree_util.tree_map(
            lambda a, b: a.at[dest].set(b, mode="drop"), lat.items, payload
        )
        return lt.Latent(items=items, nfull=lat.nfull, weight=jnp.float32(n))

    def undershoot():
        # lines 19-20: downsample to W' = W - B, then accept all batch items
        l2 = lt.downsample(k_ds, lat, w_new - bcapf)
        return lt.insert_full(l2, batch_items, bcount)

    out = jax.lax.cond(w_new >= n, still_saturated, undershoot)
    return out, w_new


@functools.partial(jax.jit, static_argnames=("n",))
def step(
    key: jax.Array,
    state: RTBSState,
    batch_items: Any,
    bcount: jax.Array,
    *,
    n: int,
    lam: float | jax.Array,
) -> RTBSState:
    """Advance R-TBS by one batch arrival (paper Algorithm 2).

    ``batch_items``: pytree, leaves [bcap, ...]; valid prefix length ``bcount``.
    ``lam`` may be a traced scalar; elapsed time between batches is 1 (use
    lam * dt for irregular arrivals, per paper Sec. 2).
    """
    decay = jnp.exp(-jnp.asarray(lam, jnp.float32))
    bcount = jnp.asarray(bcount, jnp.int32)
    was_unsat = state.total_weight < n
    lat, w_new = jax.lax.cond(
        was_unsat,
        lambda: _unsaturated_path(
            key, state.lat, state.total_weight, batch_items, bcount, n, decay
        ),
        lambda: _saturated_path(
            key, state.lat, state.total_weight, batch_items, bcount, n, decay
        ),
    )
    return RTBSState(lat=lat, total_weight=w_new)


def realize(key: jax.Array, state: RTBSState) -> tuple[jax.Array, jax.Array]:
    """Draw the actual sample S_t: (mask over the n+1 slots, |S_t|)."""
    return lt.realize(key, state.lat)


def run_stream(
    key: jax.Array,
    state: RTBSState,
    batches: Any,
    bcounts: jax.Array,
    *,
    n: int,
    lam: float,
) -> tuple[RTBSState, dict]:
    """Scan ``step`` over a stream of T batches; returns final state + per-step
    trace (sample weight C_t, total weight W_t, realized size E via C)."""

    def body(carry, inp):
        st = carry
        items_t, cnt_t, key_t = inp
        st = step(key_t, st, items_t, cnt_t, n=n, lam=lam)
        return st, {"C": st.lat.weight, "W": st.total_weight}

    T = bcounts.shape[0]
    keys = jax.random.split(key, T)
    final, trace = jax.lax.scan(body, state, (batches, bcounts, keys))
    return final, trace
