"""Paper-literal reference implementations (pure Python objects + floats).

These follow the paper's pseudocode line-by-line with explicit sets and are the
oracles for the fixed-shape JAX implementations: both are Monte-Carlo tested
against the analytic inclusion-probability invariants (eq. (1), eq. (4),
Theorem 3.1(ii), Theorem 4.1), and the trajectories of the *deterministic*
bookkeeping scalars (W_t, C_t) must match the JAX versions exactly.

Also hosts B-Chao (paper Appendix D, Algorithms 6+7) -- the prior-art baseline
that *fails* eq. (1) during fill-up and under slow arrival rates; we reproduce
that failure in the benchmarks, as the paper does analytically.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field


def _frac(x: float) -> float:
    return x - math.floor(x)


@dataclass
class RefLatent:
    """Latent sample L = (A, pi, C): full items, <=1 partial item, weight C."""

    full: list = field(default_factory=list)
    partial: object = None
    weight: float = 0.0

    def realize(self, rnd: random.Random) -> list:
        s = list(self.full)
        f = _frac(self.weight)
        if self.partial is not None and f > 0 and rnd.random() < f:
            s.append(self.partial)
        return s


def ref_downsample(rnd: random.Random, lat: RefLatent, new_weight: float) -> RefLatent:
    """Paper Algorithm 3 (verbatim case analysis)."""
    C, Cp = lat.weight, new_weight
    assert 0 < Cp <= C, (Cp, C)
    if Cp >= C:
        return lat
    A = list(lat.full)
    pi = lat.partial
    k, kp = math.floor(C), math.floor(Cp)
    f, fp = _frac(C), _frac(Cp)
    U = rnd.random()
    if kp == 0:
        # lines 5-8: no full items retained
        if pi is not None and U <= f / C:
            new_pi = pi
        else:
            new_pi = rnd.choice(A)
        return RefLatent(full=[], partial=new_pi if fp > 0 else None, weight=Cp)
    if kp == k:
        # lines 9-11: no items deleted; maybe swap partial <-> a full item
        rho = (1.0 - (Cp / C) * f) / (1.0 - fp) if fp < 1.0 else 0.0
        if U > rho:
            a = rnd.randrange(len(A))
            new_pi = A[a]
            A = A[:a] + A[a + 1 :] + ([pi] if pi is not None else [])
            return RefLatent(full=A, partial=new_pi if fp > 0 else None, weight=Cp)
        return RefLatent(full=A, partial=pi if fp > 0 else None, weight=Cp)
    # lines 12-18: 0 < kp < k
    if pi is not None and U <= (Cp / C) * f:
        sel = rnd.sample(A, kp)
        new_pi = sel[-1]
        full = sel[:-1] + [pi]
    else:
        sel = rnd.sample(A, kp + 1)
        new_pi = sel[-1]
        full = sel[:-1]
    return RefLatent(full=full, partial=new_pi if fp > 0 else None, weight=Cp)


class RefRTBS:
    """Paper Algorithm 2, verbatim."""

    def __init__(self, n: int, lam: float, seed: int = 0):
        self.n, self.lam = n, lam
        self.rnd = random.Random(seed)
        self.lat = RefLatent()
        self.W = 0.0

    def step(self, batch: list) -> None:
        n, rnd = self.n, self.rnd
        decay = math.exp(-self.lam)
        B = len(batch)
        if self.W < n:  # previously unsaturated (lines 5-12)
            self.W = decay * self.W
            if 0 < self.W < self.lat.weight:
                self.lat = ref_downsample(rnd, self.lat, self.W)
            else:
                self.lat.weight = min(self.lat.weight, max(self.W, 0.0))
            self.lat = RefLatent(
                full=self.lat.full + list(batch),
                partial=self.lat.partial,
                weight=self.lat.weight + B,
            )
            self.W += B
            if self.lat.weight > n:
                self.lat = ref_downsample(rnd, self.lat, float(n))
        else:  # previously saturated (lines 14-20)
            self.W = decay * self.W + B
            if self.W >= n:
                m_real = B * n / self.W
                m = math.floor(m_real) + (1 if rnd.random() < _frac(m_real) else 0)
                victims = rnd.sample(range(len(self.lat.full)), m)
                inserts = rnd.sample(batch, m)
                full = list(self.lat.full)
                for v, b in zip(victims, inserts):
                    full[v] = b
                self.lat = RefLatent(full=full, partial=None, weight=float(n))
            else:
                self.lat = ref_downsample(rnd, self.lat, self.W - B)
                self.lat = RefLatent(
                    full=self.lat.full + list(batch),
                    partial=self.lat.partial,
                    weight=self.lat.weight + B,
                )

    def sample(self) -> list:
        return self.lat.realize(self.rnd)


class RefTTBS:
    """Paper Algorithm 1, verbatim."""

    def __init__(self, n: int, lam: float, b: float, seed: int = 0):
        self.p = math.exp(-lam)
        self.q = n * (1.0 - self.p) / b
        assert self.q <= 1.0 + 1e-9, "requires b >= n(1-e^-lambda)"
        self.rnd = random.Random(seed)
        self.S: list = []

    def step(self, batch: list) -> None:
        rnd = self.rnd
        m = sum(rnd.random() < self.p for _ in self.S)  # Binomial(|S|, p)
        self.S = rnd.sample(self.S, m)
        k = sum(rnd.random() < self.q for _ in batch)
        self.S = self.S + rnd.sample(list(batch), k)

    def sample(self) -> list:
        return list(self.S)


class RefBRS:
    """Paper Algorithm 5 (batched classical reservoir sampling)."""

    def __init__(self, n: int, seed: int = 0):
        self.n = n
        self.rnd = random.Random(seed)
        self.S: list = []
        self.W = 0

    @staticmethod
    def _hypergeo(rnd, k, a, b):
        """# type-a successes drawing k from a+b without replacement (exact)."""
        pop = [1] * a + [0] * b
        return sum(rnd.sample(pop, k))

    def step(self, batch: list) -> None:
        rnd, n = self.rnd, self.n
        B = len(batch)
        C = min(n, self.W + B)
        M = self._hypergeo(rnd, C, B, self.W)
        keep = min(n - M, len(self.S))
        self.S = rnd.sample(self.S, keep) + rnd.sample(list(batch), M)
        self.W += B

    def sample(self) -> list:
        return list(self.S)


class RefBChao:
    """Paper Appendix D: batched, time-decayed Chao [9] (Algorithms 6+7).

    Maintains per-item weights, tracks overweight items (set V) explicitly, and
    -- as the paper proves -- violates eq. (1) during fill-up and whenever data
    arrives slowly relative to the decay rate. Kept as the prior-art baseline.
    """

    def __init__(self, n: int, lam: float, seed: int = 0):
        self.n, self.lam = n, lam
        self.rnd = random.Random(seed)
        self.S: list = []          # non-overweight items in the reservoir
        self.W = 0.0               # aggregate weight of non-overweight items
        self.V: list = []          # [(item, weight)] overweight items
        self.A: list = []          # newly non-overweight (transient, per item)

    def _normalize(self, x):
        """Algorithm 7. Returns (pi_x, x_is_overweight); mutates V/A/W."""
        n = self.n
        W = self.W + 1.0 + sum(w for _, w in self.V)
        self.A = []
        if n / W <= 1.0:
            self.A = list(self.V)
            self.V = []
            self.W = W
            return n / W, False
        # x itself is overweight
        pi_x = 1.0
        W -= 1.0
        D = [(x, 1.0)]
        V = sorted(self.V, key=lambda t: -t[1])
        while V:
            z, wz = V[0]
            if (n - len(D)) * wz / W > 1.0:
                D.append((z, wz))
                W -= wz
                V = V[1:]
            else:
                break
        self.A = V
        self.V = D
        self.W = W
        return pi_x, True

    def step(self, batch: list) -> None:
        rnd, n = self.rnd, self.n
        decay = math.exp(-self.lam)
        self.W *= decay
        self.V = [(z, w * decay) for z, w in self.V]
        for x in batch:
            if len(self.S) + len(self.V) < n:
                self.S.append(x)
                self.W += 1.0
                continue
            pi_x, x_over = self._normalize(x)
            if rnd.random() <= pi_x:
                # choose a victim: from A w.p. (1 - (n-|V|) w_z / W)/pi_x each,
                # else uniform from S
                y = None
                alpha = 0.0
                U = rnd.random()
                for z, wz in self.A:
                    alpha += (1.0 - (n - len(self.V)) * wz / self.W) / pi_x
                    if U <= alpha:
                        y = (z, wz)
                        break
                if y is not None:
                    self.A.remove(y)
                else:
                    y_idx = rnd.randrange(len(self.S))
                    self.S = self.S[:y_idx] + self.S[y_idx + 1 :]
                if not x_over:  # Alg.6 line 20: if (x,1) not in V
                    self.S.append(x)
            # Alg.6 line 21: newly non-overweight items re-enter S
            self.S.extend(z for z, _ in self.A)
            self.A = []

    def sample(self) -> list:
        return list(self.S) + [z for z, _ in self.V]


class RefSW:
    """Sliding window over the last n items (baseline "SW")."""

    def __init__(self, n: int, seed: int = 0):
        self.n = n
        self.S: list = []

    def step(self, batch: list) -> None:
        self.S = (self.S + list(batch))[-self.n :]

    def sample(self) -> list:
        return list(self.S)
