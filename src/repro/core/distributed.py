"""D-R-TBS and D-T-TBS: the paper's Section-5 distributed algorithms on a JAX mesh.

Spark -> TPU mapping (see DESIGN.md Sec. 3):

  * co-partitioned reservoir  -> reservoir shard s lives with incoming-batch
    shard s along the ``data`` mesh axis; item payloads NEVER cross shards
    (except the single fractional item, whose payload is replicated).
  * distributed decisions     -> every shard computes the identical global
    bookkeeping from the same PRNG key, splits global insert/delete counts
    with an exact multivariate hypergeometric over per-shard counts
    (Sec. 5.3 / Fig. 6(b)), then acts only on its own shard.
  * master aggregation of |B_t| -> one scalar ``psum`` per step.

The module is written against *per-shard* views: every public ``*_shard_step``
function is meant to be called inside ``jax.shard_map`` over the ``data`` axis
(helpers to build those wrappers are provided at the bottom). All global
bookkeeping (W, C, branch choices, count splits) is computed identically on
every shard from the replicated scalars + shared key, so no scalar needs to be
exchanged beyond the |B_t| psum and the tiny all_gather of per-shard counts.

Variants kept for the paper's Figure-7 comparison:
  * centralized decisions (global permutation over virtual slots, replicated)
  * key-value-store reservoir emulation (hash-partitioned: batch payloads must
    cross the network -- modeled with an all_gather of insert payloads)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import latent as lt
from . import rng

AXIS = "data"  # mesh axis the reservoir is co-partitioned over


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions: new-style (``check_vma``) when
    available, else ``jax.experimental.shard_map`` (``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DRTBSShard:
    """Per-shard slice of the distributed latent sample.

    Global latent = union of shard full-item prefixes + one replicated partial.
    ``weight``/``total_weight``/``partial_*`` are replicated scalars (identical
    on every shard -- enforced by construction, all derived from shared keys).
    """

    items: Any                # pytree, leaves [cap_s, ...] -- full items at [0, nfull)
    nfull: jax.Array          # int32, this shard's full-item count
    partial_item: Any         # pytree of ONE item (replicated payload)
    weight: jax.Array         # float32, global C
    total_weight: jax.Array   # float32, global W
    overflow: jax.Array       # int32, capacity-dropped inserts (should stay 0)


def init_shard(item_proto: Any, cap_s: int) -> DRTBSShard:
    """Empty per-shard state (call under shard_map or vmap over shards)."""
    items = jax.tree_util.tree_map(
        lambda p: jnp.zeros((cap_s,) + tuple(p.shape), p.dtype), item_proto
    )
    one = jax.tree_util.tree_map(
        lambda p: jnp.zeros(tuple(p.shape), p.dtype), item_proto
    )
    return DRTBSShard(
        items=items,
        nfull=jnp.int32(0),
        partial_item=one,
        weight=jnp.float32(0.0),
        total_weight=jnp.float32(0.0),
        overflow=jnp.int32(0),
    )


def _payload_bcast(payload: Any, flag) -> Any:
    """Zero out payload unless flag; psum over shards -> replicated broadcast."""
    return jax.tree_util.tree_map(
        lambda p: jax.lax.psum(p * jnp.asarray(flag, p.dtype), AXIS), payload
    )


# ---------------------------------------------------------------------------
# the global downsample, executed shard-locally (paper Alg. 3, distributed)
# ---------------------------------------------------------------------------
def _dist_downsample(key, st: DRTBSShard, new_weight) -> DRTBSShard:
    """Distributed Algorithm 3: scale every item's inclusion prob by C'/C.

    All shards derive the same branch decisions and count split from ``key``;
    each then compacts only its local prefix. The new partial item's payload is
    broadcast with one psum. Old-partial-as-full lands on the donor shard."""
    cap_s = jax.tree_util.tree_leaves(st.items)[0].shape[0]
    me = jax.lax.axis_index(AXIS)
    nshards = jax.lax.psum(1, AXIS)

    cw = st.weight
    nw = jnp.minimum(jnp.asarray(new_weight, jnp.float32), cw)
    k, f = lt.floor_frac(cw)
    kp, fp = lt.floor_frac(nw)
    safe_c = jnp.maximum(cw, 1e-30)

    k_u, k_split, k_donor, k_local = jax.random.split(key, 4)
    u = jax.random.uniform(k_u, dtype=jnp.float32)

    counts = jax.lax.all_gather(st.nfull, AXIS)  # [S] replicated

    # --- shared branch logic -------------------------------------------------
    case0 = kp == 0
    case_eq = (kp == k) & ~case0
    # case_lt otherwise
    p1 = (nw / safe_c) * f
    b1 = (u <= p1) & (f > 0)                      # case_lt branch 1
    rho = (1.0 - (nw / safe_c) * f) / jnp.maximum(1.0 - fp, 1e-30)
    do_swap = u > rho                             # case_eq swap?
    keep_old_partial = u <= f / safe_c            # case0

    # Number of items to select globally from the full-item pool:
    #   case0: 1 (only if a full item becomes the partial)
    #   case_eq: 1 (only to swap)   case_lt b1: kp   case_lt b2: kp + 1
    sel_total = jnp.where(
        case0,
        jnp.where(keep_old_partial, 0, 1),
        jnp.where(
            case_eq,
            jnp.where(do_swap, 1, 0),
            jnp.where(b1, kp, kp + 1),
        ),
    )
    split = rng.multivariate_hypergeometric(
        k_split, sel_total, counts, max_support=cap_s
    )  # [S] replicated
    x_s = split[me]

    # Donor shard for the new partial: w.p. x_s / sel_total.
    donor_shard = rng.categorical_from_counts(k_donor, split)
    is_donor = (me == donor_shard) & (sel_total > 0)

    # --- local compaction ----------------------------------------------------
    perm = rng.prefix_permutation_fast(
        jax.random.fold_in(k_local, me), cap_s, st.nfull
    )
    # fulls kept locally:
    #   case0: 0.     case_eq: nfull (swap only replaces one slot -- see below)
    #   case_lt: x_s, minus 1 on the donor (its last selected becomes partial;
    #            if fp==0 that extracted item is simply dropped, which matches
    #            Alg. 3 lines 19-20 exactly -- see tests).
    keep_s = jnp.where(
        case0,
        0,
        jnp.where(case_eq, st.nfull, x_s - jnp.where(is_donor, 1, 0)),
    ).astype(jnp.int32)
    keep_s = jnp.maximum(keep_s, 0)

    # new partial payload (uniform over the globally selected items):
    #   donor contributes its perm[keep_s] item (for case_lt) / perm[0] (case0/eq)
    donor_slot = jnp.where(case0 | case_eq, perm[0], perm[jnp.minimum(keep_s, cap_s - 1)])
    donor_payload = jax.tree_util.tree_map(lambda a: a[donor_slot], st.items)
    new_partial_from_full = _payload_bcast(donor_payload, is_donor)
    new_partial = jax.tree_util.tree_map(
        lambda old, new: jnp.where(
            _b(case0 & keep_old_partial | (case_eq & ~do_swap), old), old, new
        ),
        st.partial_item,
        new_partial_from_full,
    )

    # --- rebuild local buffer -------------------------------------------------
    # case_lt / case0: gather-compact to the first keep_s of perm order
    compacted = lt.gather(st.items, perm)

    # case_eq swap: replace slot perm[0] (the item that became partial) with the
    # old partial payload, keep everything else in place.
    swapped = jax.tree_util.tree_map(
        lambda a, p: a.at[perm[0]].set(
            jnp.where(_b(is_donor & (f > 0), p), p, a[perm[0]])
        ),
        st.items,
        st.partial_item,
    )
    items = jax.tree_util.tree_map(
        lambda comp, sw: jnp.where(_b2(case_eq, comp), sw, comp), compacted, swapped
    )
    nfull_new = jnp.where(case_eq, st.nfull, keep_s).astype(jnp.int32)

    # case_lt branch1: old partial becomes a FULL item -> append on donor shard.
    append_old_partial = (~case0) & (~case_eq) & b1 & (f > 0)
    items = jax.tree_util.tree_map(
        lambda a, p: a.at[jnp.where(append_old_partial & is_donor, nfull_new, cap_s)]
        .set(p, mode="drop"),
        items,
        st.partial_item,
    )
    nfull_new = nfull_new + jnp.where(append_old_partial & is_donor, 1, 0)

    # identity shortcut when no shrink requested
    noop = nw >= cw
    items = jax.tree_util.tree_map(
        lambda old, new: jnp.where(_b2(noop, old), old, new), st.items, items
    )
    nfull_new = jnp.where(noop, st.nfull, nfull_new)
    new_partial = jax.tree_util.tree_map(
        lambda old, new: jnp.where(_b(noop, old), old, new),
        st.partial_item,
        new_partial,
    )

    return dataclasses.replace(
        st, items=items, nfull=nfull_new, partial_item=new_partial, weight=nw
    )


def _b(pred, like):
    """broadcast scalar bool for a single-item payload leaf"""
    return jnp.reshape(pred, (1,) * like.ndim) if like.ndim else pred


def _b2(pred, like):
    """broadcast scalar bool for a [cap, ...] buffer leaf"""
    return jnp.reshape(pred, (1,) * like.ndim)


def _local_insert_full(st: DRTBSShard, batch_items, bcount, add_weight) -> DRTBSShard:
    """Append local batch items as full items (weight bump is the GLOBAL batch
    size; item placement is purely local -- co-partitioned reservoir)."""
    cap_s = jax.tree_util.tree_leaves(st.items)[0].shape[0]
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]
    i = jnp.arange(bcap, dtype=jnp.int32)
    dest = jnp.where(i < bcount, st.nfull + i, cap_s)
    dropped = jnp.maximum(st.nfull + bcount - cap_s, 0)
    items = jax.tree_util.tree_map(
        lambda a, b: a.at[dest].set(b, mode="drop"), st.items, batch_items
    )
    return dataclasses.replace(
        st,
        items=items,
        nfull=jnp.minimum(st.nfull + bcount, cap_s),
        weight=st.weight + jnp.asarray(add_weight, jnp.float32),
        overflow=st.overflow + dropped,
    )


# ---------------------------------------------------------------------------
# the per-batch step (paper Alg. 2, distributed; call under shard_map)
# ---------------------------------------------------------------------------
def drtbs_shard_step(
    key: jax.Array,
    st: DRTBSShard,
    batch_items: Any,
    bcount_local: jax.Array,
    *,
    n: int,
    lam=None,
    decay=None,
) -> DRTBSShard:
    """One D-R-TBS step for this shard. ``key`` must be IDENTICAL across shards
    (replicated); shard-local draws fold in the shard index. ``decay`` gives
    the per-tick multiplicative factor d_t directly (replicated, possibly
    traced -- the :mod:`repro.decay` form) instead of the rate ``lam``;
    exactly one of the two must be passed."""
    from . import rtbs as _rtbs

    me = jax.lax.axis_index(AXIS)
    decay = _rtbs._resolve_decay(lam, decay)
    bcount_local = jnp.asarray(bcount_local, jnp.int32)
    B = jax.lax.psum(bcount_local, AXIS)            # the ONE aggregation (Sec. 5.1)
    Bf = B.astype(jnp.float32)
    cap_s = jax.tree_util.tree_leaves(st.items)[0].shape[0]
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]

    k_ds, k_over, k_m, k_split_v, k_split_i, k_loc = jax.random.split(key, 6)
    was_unsat = st.total_weight < n

    def unsat_path(st: DRTBSShard) -> DRTBSShard:
        w_dec = decay * st.total_weight
        st1 = jax.lax.cond(
            (w_dec > 0) & (w_dec < st.weight),
            lambda: _dist_downsample(k_ds, st, w_dec),
            lambda: dataclasses.replace(
                st, weight=jnp.minimum(st.weight, jnp.maximum(w_dec, 0.0))
            ),
        )
        st2 = _local_insert_full(st1, batch_items, bcount_local, Bf)
        w_new = w_dec + Bf
        st3 = jax.lax.cond(
            st2.weight > n,
            lambda: _dist_downsample(k_over, st2, jnp.float32(n)),
            lambda: st2,
        )
        return dataclasses.replace(st3, total_weight=w_new)

    def sat_path(st: DRTBSShard) -> DRTBSShard:
        w_new = decay * st.total_weight + Bf

        def still_saturated():
            m = rng.stochastic_round(k_m, Bf * n / jnp.maximum(w_new, 1e-30))
            counts = jax.lax.all_gather(st.nfull, AXIS)
            bcounts = jax.lax.all_gather(bcount_local, AXIS)
            # paper Fig. 6(b): split delete AND insert counts hypergeometrically
            del_s = rng.multivariate_hypergeometric(
                k_split_v, m, counts, max_support=cap_s
            )[me]
            ins_s = rng.multivariate_hypergeometric(
                k_split_i, m, bcounts, max_support=bcap
            )[me]
            k_vic, k_pick = jax.random.split(jax.random.fold_in(k_loc, me))
            # delete del_s local victims by compaction to (nfull - del_s) ...
            vperm = rng.prefix_permutation_fast(k_vic, cap_s, st.nfull)
            keep = st.nfull - del_s
            compacted = lt.gather(st.items, vperm)
            # ... then append ins_s local batch picks
            picks = rng.prefix_permutation_fast(k_pick, bcap, bcount_local)
            i = jnp.arange(bcap, dtype=jnp.int32)
            dest = jnp.where(i < ins_s, keep + i, cap_s)
            dropped = jnp.maximum(keep + ins_s - cap_s, 0)
            payload = lt.gather(batch_items, picks)
            items = jax.tree_util.tree_map(
                lambda a, b: a.at[dest].set(b, mode="drop"), compacted, payload
            )
            return dataclasses.replace(
                st,
                items=items,
                nfull=jnp.minimum(keep + ins_s, cap_s),
                weight=jnp.float32(n),
                overflow=st.overflow + dropped,
            )

        def undershoot():
            st1 = _dist_downsample(k_ds, st, w_new - Bf)
            return _local_insert_full(st1, batch_items, bcount_local, Bf)

        st2 = jax.lax.cond(w_new >= n, still_saturated, undershoot)
        return dataclasses.replace(st2, total_weight=w_new)

    return jax.lax.cond(was_unsat, unsat_path, sat_path, st)


def drtbs_realize_shard(key: jax.Array, st: DRTBSShard):
    """Realize S_t on this shard: (mask [cap_s], local size). The partial item is
    included (on shard 0 only) w.p. frac(C), using the replicated key.

    Callers that materialize the realized sample must also materialize the
    partial payload whenever ``take_partial`` is True -- ``st.partial_item`` is
    NOT covered by ``mask``/``st.items`` (it is a separate replicated payload).
    The unified API does this by reserving slot ``cap_s``; see
    :func:`repro.core.api._make_drtbs`."""
    me = jax.lax.axis_index(AXIS)
    _, take, _ = lt.partial_draw(key, st.weight)
    take_partial = take & (me == 0)
    cap_s = jax.tree_util.tree_leaves(st.items)[0].shape[0]
    mask = jnp.arange(cap_s) < st.nfull
    return mask, st.nfull + take_partial.astype(jnp.int32), take_partial


def drtbs_realize_global(key: jax.Array, st: DRTBSShard):
    """Assemble the realized GLOBAL sample, replicated on every shard.

    Returns ``(items, mask, size)`` where item leaves are [S*cap_s + 1, ...]:
    the all-gathered per-shard full-item buffers followed by ONE reserved slot
    holding the replicated partial payload. Slot ``S*cap_s`` is selected w.p.
    frac(C) with the replicated key, so -- unlike the bare per-shard realize --
    the fractional item's payload is materialized whenever it is counted and
    ``mask.sum() == size`` holds globally. One all_gather of the shard buffers
    (the only time payloads cross shards: model fitting needs them anyway) plus
    one psum of the counts."""
    cap_s = jax.tree_util.tree_leaves(st.items)[0].shape[0]
    _, take_partial, _ = lt.partial_draw(key, st.weight)
    mask_s = jnp.arange(cap_s) < st.nfull
    items = jax.tree_util.tree_map(
        lambda a: jax.lax.all_gather(a, AXIS, tiled=True), st.items
    )
    mask = jax.lax.all_gather(mask_s, AXIS, tiled=True)
    items = jax.tree_util.tree_map(
        lambda g, p: jnp.concatenate([g, p[None]], axis=0), items, st.partial_item
    )
    mask = jnp.concatenate([mask, take_partial[None]])
    size = jax.lax.psum(st.nfull, AXIS) + take_partial.astype(jnp.int32)
    return items, mask, size


def drtbs_global_size(key: jax.Array, st: DRTBSShard) -> jax.Array:
    """|S_t| as :func:`drtbs_realize_global` would report it (same key => same
    partial-item draw), without touching the item buffers: the cheap size-only
    path the fused loop logs on non-retrain ticks."""
    _, take_partial, _ = lt.partial_draw(key, st.weight)
    return jax.lax.psum(st.nfull, AXIS) + take_partial.astype(jnp.int32)


def buffer_realize_global(state):
    """Global view of a per-shard :class:`repro.core.simple.BufferState` (the
    D-T-TBS path): all-gathered buffers + prefix masks, psum'd size. Replicated
    on every shard; deterministic membership, so no key."""
    from . import simple

    mask_s, _ = simple.realize_all(state)
    items = jax.tree_util.tree_map(
        lambda a: jax.lax.all_gather(a, AXIS, tiled=True), state.items
    )
    mask = jax.lax.all_gather(mask_s, AXIS, tiled=True)
    size = jax.lax.psum(state.count, AXIS)
    return items, mask, size


def gather_tree(tree: Any, axis: str = AXIS) -> Any:
    """Replicated global snapshot of per-shard state: every leaf gains a
    leading [S] axis (scalars become [S] vectors). ``tree_map(lambda a: a[me],
    snapshot)`` inside shard_map restores the per-shard view bit-exactly, which
    is how the per-tick sharded driver round-trips state between dispatches."""
    return jax.tree_util.tree_map(lambda a: jax.lax.all_gather(a, axis), tree)


# ---------------------------------------------------------------------------
# D-T-TBS: embarrassingly parallel (paper Sec. 5.1)
# ---------------------------------------------------------------------------
def dttbs_shard_step(key, state, batch_items, bcount_local, *, p, q):
    """Each shard runs T-TBS on its own partition -- zero coordination."""
    from . import simple

    me = jax.lax.axis_index(AXIS)
    return simple.ttbs_step(
        jax.random.fold_in(key, me), state, batch_items, bcount_local, p=p, q=q
    )


# ---------------------------------------------------------------------------
# mesh-level wrappers
# ---------------------------------------------------------------------------
def make_drtbs_step(mesh, item_spec, *, n: int, lam: float, axis: str = AXIS):
    """Build a pjit-able whole-mesh D-R-TBS step via shard_map over ``axis``.

    item_spec: PartitionSpec for item buffers' leading (global slot) dim."""
    from jax.sharding import PartitionSpec as P

    def sharded(key, st, batch_items, bcounts):
        return drtbs_shard_step(key, st, batch_items, bcounts, n=n, lam=lam)

    state_specs = DRTBSShard(
        items=item_spec,
        nfull=P(axis),
        partial_item=P(),
        weight=P(),
        total_weight=P(),
        overflow=P(axis),
    )
    return jax.jit(
        shard_map(
            sharded,
            mesh=mesh,
            in_specs=(P(), state_specs, item_spec, P(axis)),
            out_specs=state_specs,
        )
    )
