"""Latent ("fractional") samples -- the core data structure of R-TBS (paper Sec. 4.1).

A latent sample L = (A, pi, C) holds floor(C) "full" items and at most one
"partial" item; realizing a sample S from L includes every full item and the
partial item with probability frac(C), so E[|S|] = C exactly (paper eq. (3)).

Fixed-shape JAX encoding (jit/scan/shard_map-safe):
  * ``items``   -- a pytree whose leaves have leading dim ``cap``
  * ``nfull``   -- int32, floor(C): slots [0, nfull) hold the full items
  * ``weight``  -- float32, the sample weight C; if frac(C) > 0 the partial item
                   lives at slot ``nfull``; slots above are garbage.

The key operator is :func:`downsample` (paper Algorithm 3), which rescales every
item's inclusion probability by exactly C'/C (Theorem 4.1). We implement it as a
branch-selected gather: each branch produces a slot-index map ``src`` (new slot ->
old slot), exposed on its own as :func:`downsample_map` so the fused R-TBS step
can compose a whole tick's rewrites into ONE two-source payload pass (the
``tbs_step`` Pallas kernel; DESIGN.md Sec. 11). :func:`realize_compact` packs a
realized sample to the buffer head via the ``reservoir_compact`` kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import rng


def _f32(x):
    return jnp.asarray(x, jnp.float32)


def floor_frac(c):
    """(floor(C) as int32, frac(C) in [0,1)) with float-noise clipping."""
    c = _f32(c)
    k = jnp.floor(c)
    return k.astype(jnp.int32), jnp.clip(c - k, 0.0, 1.0)


def partial_draw(key: jax.Array, weight) -> tuple[jax.Array, jax.Array, jax.Array]:
    """THE fractional-item realization draw: (floor(C), take_partial, frac(C)).

    ``take_partial`` is True w.p. frac(C) (False when frac == 0). Every
    realization path -- :func:`realize`, the Samplers' size-only fast paths,
    and the distributed per-shard/global realizes -- MUST consume the key
    through this one helper so ``mask.sum() == size`` and size == extract's
    ``view.size`` stay structural invariants rather than five copies of the
    same bernoulli."""
    k, f = floor_frac(weight)
    take = jax.random.bernoulli(key, f) & (f > 0)
    return k, take, f


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Latent:
    """Latent fractional sample; see module docstring for slot conventions."""

    items: Any            # pytree, leaves [cap, ...]
    nfull: jax.Array      # int32 scalar
    weight: jax.Array     # float32 scalar (C)

    @property
    def cap(self) -> int:
        return jax.tree_util.tree_leaves(self.items)[0].shape[0]

    def has_partial(self) -> jax.Array:
        _, f = floor_frac(self.weight)
        return f > 0


def gather(items: Any, idx: jax.Array) -> Any:
    """tree-wide items[idx] (fill_value semantics unused: callers keep idx in range)."""
    return jax.tree_util.tree_map(lambda a: jnp.take(a, idx, axis=0), items)


def make_empty(item_proto: Any, cap: int) -> Latent:
    """Empty latent sample with capacity ``cap``; item_proto gives leaf shapes/dtypes
    (a pytree of arrays or ShapeDtypeStructs describing ONE item)."""
    items = jax.tree_util.tree_map(
        lambda p: jnp.zeros((cap,) + tuple(p.shape), p.dtype), item_proto
    )
    return Latent(items=items, nfull=jnp.int32(0), weight=jnp.float32(0.0))


def realize(key: jax.Array, lat: Latent) -> tuple[jax.Array, jax.Array]:
    """Draw S from L per paper eq. (2): returns (mask[cap] bool, size int32).

    Full slots are always included; the partial slot is included w.p. frac(C).
    """
    k, take_partial, _ = partial_draw(key, lat.weight)
    slot = jnp.arange(lat.cap, dtype=jnp.int32)
    mask = (slot < k) | ((slot == k) & take_partial)
    return mask, k + take_partial.astype(jnp.int32)


def compact_items(items: Any, mask: jax.Array) -> Any:
    """Tree-wide stable pack of the masked rows to the buffer head via the
    :mod:`repro.kernels.reservoir_compact` kernel (Pallas on TPU, jnp oracle
    elsewhere). Leaves may have any trailing shape (flattened to [cap, D]);
    rows past ``mask.sum()`` come back zero. THE pack primitive behind every
    materialization path (:func:`realize_compact` here,
    :func:`repro.core.api.materialize_view` and through it the distributed
    ``extract_global`` closures)."""
    from repro.kernels.reservoir_compact import ops as rc_ops

    def pack(leaf):
        flat = leaf.reshape(leaf.shape[0], -1)
        out, _ = rc_ops.reservoir_compact(flat, mask)
        return out.reshape(leaf.shape)

    return jax.tree_util.tree_map(pack, items)


def realize_compact(key: jax.Array, lat: Latent) -> tuple[Any, jax.Array]:
    """Materialize S: draw the realization mask and pack the selected rows to
    the buffer head (:func:`compact_items`). Returns ``(items, size)`` where
    item leaves are [cap, ...] with rows [0, size) the sample and the rest
    zero. Consumes the key exactly like :func:`realize` (same partial draw).
    """
    mask, size = realize(key, lat)
    return compact_items(lat.items, mask), size


def _downsample_map_small(key: jax.Array, cap: int, k, f, kp, fp, nw, cw,
                          D: int) -> jax.Array:
    """Delete-complement construction of the Alg. 3 slot map: O(D) random
    work instead of a full-domain PRP evaluation (DESIGN.md Sec. 12).

    Valid when at most ``D`` full items leave the full set (``k - kp <= D``)
    or when no full item is deleted at all (kp == 0 / kp == k, which need
    only ONE uniform full-slot draw). Instead of drawing a length-``cap``
    prefix permutation and *keeping* its head, delete the complement: repeat
    ``d`` times "remove a uniform slot of the current prefix [0, m) by
    moving the item at m-1 into it" -- the classic swap-with-last deletion,
    each step uniform over the remaining items, so the surviving set is an
    exact uniform (k-d)-subset. A final uniform swap positions the new
    partial item uniformly among the survivors. Full items are exchangeable
    beyond the full/partial split, so survivor ORDER is free -- exactly the
    freedom the full-permutation construction also exploits.

    Same distribution as the ``prefix_permutation_fast`` path (Theorem 4.1
    re-verified in tests), different RNG stream.
    """
    kperm, ku = jax.random.split(key)
    u = jax.random.uniform(ku, dtype=jnp.float32)
    # D victim draws + one uniform-full draw + one survivor draw, all from
    # raw bits (modulo bias <= m / 2^32: orders below the MC tolerance of
    # every Thm 4.1/4.2 check, same budget as rng.swap_or_not)
    rb = jax.random.bits(kperm, (D + 2,), jnp.uint32)
    slot = jnp.arange(cap, dtype=jnp.int32)
    identity = slot
    safe_c = jnp.maximum(cw, 1e-30)

    def unif(bits, m):  # uniform int32 in [0, m), m >= 1 traced
        return (bits % jnp.maximum(m, 1).astype(jnp.uint32)).astype(jnp.int32)

    unif_full = unif(rb[D], k)                    # one uniform full slot

    # ---- case kp == 0 (paper Alg.3 lines 5-8): no loop needed ----
    keep_old = u <= f / safe_c
    src_case0 = identity.at[0].set(jnp.where(keep_old, k, unif_full))

    # ---- case 0 < kp == k (lines 9-11): swap partial <-> uniform full ----
    rho = (1.0 - (nw / safe_c) * f) / jnp.maximum(1.0 - fp, 1e-30)
    do_swap = u > rho
    src_swap = identity.at[unif_full].set(k).at[k].set(unif_full)
    src_case_eq = jnp.where(do_swap, src_swap, identity)

    # ---- case 0 < kp < k (lines 12-18): delete-complement ----
    p1 = (nw / safe_c) * f
    b1 = u <= p1
    # branch1 keeps kp of the k fulls (old partial joins as a full);
    # branch2 keeps kp + 1 (one of them becomes the new partial)
    d = jnp.where(b1, k - kp, k - kp - 1)

    def delete(i, src):
        m = k - i                                  # current prefix length
        v = unif(rb[jnp.minimum(i, D - 1)], m)
        return src.at[v].set(src[jnp.clip(m - 1, 0, cap - 1)])

    # dynamic trip count: only the ACTUAL deletions run (a decay tick trims
    # ~(1-d_t)C items, typically far below the static bound D); zero trips
    # for the loop-free cases
    trips = jnp.where((kp > 0) & (kp < k), jnp.clip(d, 0, D), 0)
    src_lt = jax.lax.fori_loop(0, trips, delete, identity)
    # branch2: survivors at [0, kp+1); uniform one of them becomes the
    # partial at slot kp (swap j <-> kp)
    j2 = unif(rb[D + 1], kp + 1)
    sj2, sk2 = src_lt[j2], src_lt[jnp.minimum(kp, cap - 1)]
    src_b2 = src_lt.at[kp].set(sj2).at[j2].set(sk2)
    # branch1: survivors at [0, kp); uniform one becomes the partial at slot
    # kp, its hole filled by the last survivor, old partial lands at kp-1
    kp_m1 = jnp.maximum(kp - 1, 0)
    j1 = unif(rb[D + 1], kp)
    sj1, slast = src_lt[j1], src_lt[kp_m1]
    src_b1 = src_lt.at[kp].set(sj1).at[j1].set(slast).at[kp_m1].set(k)
    src_case_lt = jnp.where(b1, src_b1, src_b2)

    src = jnp.where(
        kp == 0,
        src_case0,
        jnp.where(kp == k, src_case_eq, src_case_lt),
    )
    return jnp.where(nw >= cw, identity, src)


def downsample_map(
    key: jax.Array, cap: int, nfull, weight, new_weight, *,
    exact: bool = False, max_deleted: int | None = None
) -> jax.Array:
    """Slot-index map of paper Algorithm 3: ``src[cap]`` (new slot -> old slot)
    such that gathering the old buffer through ``src`` realizes the
    C -> C' downsample (Theorem 4.1). Map-only form so the fused R-TBS step
    (:func:`repro.core.rtbs.step`) can compose several buffer rewrites into a
    single payload pass; :func:`downsample` is map + gather.

    Randomness defaults to the argsort-free
    :func:`repro.core.rng.prefix_permutation_fast`; ``exact=True`` restores
    the exact-but-O(cap log cap) argsort draw (the pre-fused RNG stream --
    see DESIGN.md Sec. 11 -- used by the reference step and parity tests).

    ``max_deleted`` (static) enables the delete-complement fast path
    (:func:`_downsample_map_small`): whenever at most ``max_deleted`` full
    items leave the full set -- the common fill-up-phase case, where each
    tick's decay trims a sliver off a large sample -- the map is built with
    O(max_deleted) random work under a ``lax.cond`` instead of evaluating
    the PRP over the whole domain; larger trims fall back to the full
    construction at runtime. Identical distribution either way (different
    RNG stream); ignored when ``exact=True``.
    """
    del nfull  # the map depends on floor(weight) only; kept for signature clarity
    cw = _f32(weight)
    nw = jnp.minimum(_f32(new_weight), cw)
    k, f = floor_frac(cw)
    kp, fp = floor_frac(nw)

    if not exact and max_deleted is not None and max_deleted > 0:
        D = min(int(max_deleted), cap)
        can_fast = (kp == 0) | (kp == k) | (k - kp <= D)
        return jax.lax.cond(
            can_fast,
            lambda: _downsample_map_small(key, cap, k, f, kp, fp, nw, cw, D),
            lambda: _downsample_map_full(key, cap, k, f, kp, fp, nw, cw,
                                         exact),
        )
    return _downsample_map_full(key, cap, k, f, kp, fp, nw, cw, exact)


def _downsample_map_full(key, cap: int, k, f, kp, fp, nw, cw,
                         exact: bool) -> jax.Array:
    """The full-domain construction: one length-``cap`` prefix permutation,
    branch maps selected with jnp.where."""
    kperm, ku = jax.random.split(key)
    perm_fn = rng.prefix_permutation if exact else rng.prefix_permutation_fast
    perm = perm_fn(kperm, cap, k)  # random order over full slots
    u = jax.random.uniform(ku, dtype=jnp.float32)
    slot = jnp.arange(cap, dtype=jnp.int32)
    identity = slot

    safe_c = jnp.maximum(cw, 1e-30)

    # ---- case kp == 0 (no full items retained; paper Alg.3 lines 5-8) ----
    # keep old partial as partial w.p. f/C, else a uniform full item becomes partial.
    keep_old = u <= f / safe_c
    src_case0 = identity.at[0].set(jnp.where(keep_old, k, perm[0]))

    # ---- case 0 < kp == k (no items deleted; lines 9-11) ----
    # swap partial<->random-full w.p. 1-rho, rho = (1-(C'/C)f)/(1-f').
    rho = (1.0 - (nw / safe_c) * f) / jnp.maximum(1.0 - fp, 1e-30)
    do_swap = u > rho
    swap_a = perm[0]          # the full slot that becomes partial
    src_swap = identity.at[swap_a].set(k).at[k].set(swap_a)
    src_case_eq = jnp.where(do_swap, src_swap, identity)

    # ---- case 0 < kp < k (items deleted; lines 12-18) ----
    # branch1 (w.p. (C'/C)f): old partial becomes full; fulls = {pi} + perm[:kp-1];
    #                          partial = perm[kp-1].
    # branch2 (else):          fulls = perm[:kp]; partial = perm[kp].
    p1 = (nw / safe_c) * f
    b1 = u <= p1
    kp_m1 = jnp.maximum(kp - 1, 0)
    # branch1 map: new slot j<kp-1 -> perm[j]; slot kp-1 -> k (old partial);
    #              slot kp -> perm[kp-1]
    src_b1 = jnp.where(slot < kp_m1, perm[slot], identity)
    src_b1 = src_b1.at[kp_m1].set(k)
    src_b1 = src_b1.at[kp].set(perm[kp_m1])
    # branch2 map: new slot j<kp -> perm[j]; slot kp -> perm[kp]
    src_b2 = jnp.where(slot <= kp, perm[jnp.minimum(slot, cap - 1)], identity)
    src_case_lt = jnp.where(b1, src_b1, src_b2)

    src = jnp.where(
        kp == 0,
        src_case0,
        jnp.where(kp == k, src_case_eq, src_case_lt),
    )
    # C' == C shortcut (also covers the k==0,f==0 empty edge): identity.
    return jnp.where(nw >= cw, identity, src)


def downsample(key: jax.Array, lat: Latent, new_weight, *, exact: bool = False,
               max_deleted: int | None = None) -> Latent:
    """Paper Algorithm 3: rescale inclusion probabilities by C'/C (Theorem 4.1).

    Requires 0 < C' <= C (C' == C is an identity shortcut). All branches are
    computed as slot-index maps (:func:`downsample_map`, which also documents
    ``max_deleted``) and selected with jnp.where, so the whole operation is
    one gather regardless of branch.
    """
    cw = _f32(lat.weight)
    nw = jnp.minimum(_f32(new_weight), cw)
    kp, _ = floor_frac(nw)
    src = downsample_map(key, lat.cap, lat.nfull, lat.weight, new_weight,
                         exact=exact, max_deleted=max_deleted)
    new_items = gather(lat.items, src)
    return Latent(items=new_items, nfull=kp, weight=nw)


def insert_full(lat: Latent, batch_items: Any, bcount) -> Latent:
    """Insert ``bcount`` batch items (valid prefix of ``batch_items``) as FULL items,
    preserving the partial item (relocated above the inserted block).

    Paper Alg. 2 lines 9/20: arriving items are accepted with probability 1.
    Caller guarantees nfull + bcount + 1 <= cap.
    """
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]
    k = lat.nfull
    bcount = jnp.asarray(bcount, jnp.int32)

    # read the (possible) partial payload BEFORE scattering over its slot
    partial_payload = jax.tree_util.tree_map(lambda a: a[k], lat.items)

    bpos = jnp.arange(bcap, dtype=jnp.int32)
    dest = jnp.where(bpos < bcount, k + bpos, lat.cap)  # cap => dropped
    items = jax.tree_util.tree_map(
        lambda a, b: a.at[dest].set(b, mode="drop"), lat.items, batch_items
    )
    # relocate the partial item to the new top slot
    items = jax.tree_util.tree_map(
        lambda a, p: a.at[k + bcount].set(
            jnp.where(_bcast(lat.has_partial(), p), p, a[k + bcount])
        ),
        items,
        jax.tree_util.tree_map(lambda p: p, partial_payload),
    )
    return Latent(
        items=items,
        nfull=k + bcount,
        weight=lat.weight + bcount.astype(jnp.float32),
    )


def _bcast(pred, like):
    """Broadcast a scalar bool against an item payload leaf."""
    return jnp.reshape(pred, (1,) * like.ndim) if like.ndim else pred


def concat_items(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(lambda x, y: jnp.concatenate([x, y], axis=0), a, b)


def truncate_items(items: Any, cap: int) -> Any:
    return jax.tree_util.tree_map(lambda x: x[:cap], items)
