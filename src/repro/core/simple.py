"""The simpler members of the TBS family, in fixed-shape JAX form:

  * T-TBS  -- Targeted-size TBS (paper Algorithm 1): enforces eq. (1) exactly,
              controls sample size only probabilistically (Theorem 3.1), needs
              the mean batch size `b` known & constant.
  * B-TBS  -- Bernoulli TBS (paper Algorithm 4 / [32]): T-TBS with q == 1;
              no independent sample-size control.
  * B-RS   -- Batched reservoir sampling (paper Algorithm 5): bounds size,
              no time biasing (the paper's "Unif" baseline).
  * SW     -- sliding window over the last n items (the paper's "SW" baseline).

All share one state encoding: a fixed-capacity item buffer with a valid-prefix
count. T-TBS/B-TBS sample sizes are UNBOUNDED in theory (Thm 3.1(i)); the fixed
capacity is a deliberately visible engineering bound -- overflowing inserts are
dropped and counted in ``overflow`` so experiments can surface exactly the
failure mode the paper warns about (Fig. 1(a)).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import latent as lt
from . import rng


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BufferState:
    items: Any              # pytree, leaves [cap, ...]
    count: jax.Array        # int32 valid prefix
    total_weight: jax.Array  # float32 W_t (B-RS/SW: item count; T/B-TBS: decayed weight)
    overflow: jax.Array     # int32 cumulative dropped-by-capacity inserts


def init(item_proto: Any, cap: int) -> BufferState:
    items = jax.tree_util.tree_map(
        lambda p: jnp.zeros((cap,) + tuple(p.shape), p.dtype), item_proto
    )
    return BufferState(
        items=items,
        count=jnp.int32(0),
        total_weight=jnp.float32(0.0),
        overflow=jnp.int32(0),
    )


def _compact_keep(key, items, count, keep):
    """Keep a uniform random `keep`-subset of the `count` valid items, compacted
    to the buffer head. Returns (items, keep)."""
    cap = jax.tree_util.tree_leaves(items)[0].shape[0]
    perm = rng.prefix_permutation_fast(key, cap, count)
    return lt.gather(items, perm), keep


def _append(items, count, batch_items, picks, k):
    """Append k batch items (batch slots picks[:k]) at the buffer tail; drop and
    count items beyond capacity."""
    cap = jax.tree_util.tree_leaves(items)[0].shape[0]
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]
    i = jnp.arange(bcap, dtype=jnp.int32)
    dest = jnp.where(i < k, count + i, cap)
    dropped = jnp.maximum(count + k - cap, 0)
    payload = lt.gather(batch_items, picks)
    items = jax.tree_util.tree_map(
        lambda a, b: a.at[dest].set(b, mode="drop"), items, payload
    )
    new_count = jnp.minimum(count + k, cap)
    return items, new_count, dropped


@functools.partial(jax.jit, static_argnames=())
def ttbs_step(
    key: jax.Array,
    state: BufferState,
    batch_items: Any,
    bcount: jax.Array,
    *,
    p: jax.Array,
    q: jax.Array,
) -> BufferState:
    """Paper Algorithm 1. p = e^{-lambda}; q = n(1-e^{-lambda})/b."""
    k_ret, k_perm, k_acc, k_pick = jax.random.split(key, 4)
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]
    # line 6-7: retain m ~ Binomial(|S|, p) random current items
    m = rng.binomial(k_ret, state.count, p)
    items, _ = _compact_keep(k_perm, state.items, state.count, m)
    # line 8-9: accept k ~ Binomial(|B_t|, q) random batch items
    k = rng.binomial(k_acc, bcount, q)
    picks = rng.prefix_permutation_fast(k_pick, bcap, bcount)
    items, new_count, dropped = _append(items, m, batch_items, picks, k)
    # bookkeeping only (never read by the algorithm): the paper's total weight
    # W_t = sum_j B_j p^{t-j}, so drivers can log W for every scheme
    new_w = p * state.total_weight + jnp.asarray(bcount, jnp.float32)
    return BufferState(
        items=items,
        count=new_count,
        total_weight=new_w,
        overflow=state.overflow + dropped,
    )


def btbs_step(key, state, batch_items, bcount, *, p):
    """Paper Algorithm 4 (B-TBS) == T-TBS with acceptance probability q = 1."""
    return ttbs_step(key, state, batch_items, bcount, p=p, q=jnp.float32(1.0))


@functools.partial(jax.jit, static_argnames=("n",))
def brs_step(
    key: jax.Array,
    state: BufferState,
    batch_items: Any,
    bcount: jax.Array,
    *,
    n: int,
) -> BufferState:
    """Paper Algorithm 5 (batched classical reservoir sampling; "Unif")."""
    k_hg, k_perm, k_pick = jax.random.split(key, 3)
    bcount = jnp.asarray(bcount, jnp.int32)
    W = state.total_weight  # = number of items seen so far
    bf = bcount.astype(jnp.float32)
    C = jnp.minimum(jnp.float32(n), W + bf)  # new sample size (line 4)
    cap = jax.tree_util.tree_leaves(state.items)[0].shape[0]
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]
    # line 5: M ~ HyperGeo(C, |B_t|, W) -- number of new-batch items in the sample
    M = rng.hypergeometric(
        k_hg, C.astype(jnp.int32), bcount, W.astype(jnp.int32), max_support=bcap
    )
    # line 6: keep min(n - M, |S|) old items, add M batch items
    keep = jnp.minimum(jnp.int32(n) - M, state.count)
    items, _ = _compact_keep(k_perm, state.items, state.count, keep)
    picks = rng.prefix_permutation_fast(k_pick, bcap, bcount)
    items, new_count, dropped = _append(items, keep, batch_items, picks, M)
    return BufferState(
        items=items,
        count=new_count,
        total_weight=W + bf,
        overflow=state.overflow + dropped,
    )


@functools.partial(jax.jit, static_argnames=("n",))
def sw_step(
    key: jax.Array,
    state: BufferState,
    batch_items: Any,
    bcount: jax.Array,
    *,
    n: int,
) -> BufferState:
    """Sliding window over the last n items (paper baseline "SW").

    Items within the buffer are kept in arrival order (oldest first)."""
    del key  # deterministic
    bcount = jnp.asarray(bcount, jnp.int32)
    cap = jax.tree_util.tree_leaves(state.items)[0].shape[0]
    bcap = jax.tree_util.tree_leaves(batch_items)[0].shape[0]
    n32 = jnp.int32(n)
    keep_old = jnp.clip(n32 - bcount, 0, state.count)
    # oldest of the kept = count - keep_old .. count
    src = jnp.arange(cap, dtype=jnp.int32) + (state.count - keep_old)
    src = jnp.where(jnp.arange(cap) < keep_old, src, 0)
    items = lt.gather(state.items, src)
    take_new = jnp.minimum(bcount, n32)
    # newest take_new batch items = batch slots [bcount - take_new, bcount)
    bsrc = jnp.arange(bcap, dtype=jnp.int32) + (bcount - take_new)
    bsrc = jnp.clip(bsrc, 0, bcap - 1)
    items, new_count, dropped = _append(
        items, keep_old, batch_items, bsrc, take_new
    )
    return BufferState(
        items=items,
        count=new_count,
        total_weight=state.total_weight + bcount.astype(jnp.float32),
        overflow=state.overflow + dropped,
    )


def realize_all(state: BufferState) -> tuple[jax.Array, jax.Array]:
    """(mask over cap slots, count): these schemes' samples are their buffers."""
    cap = jax.tree_util.tree_leaves(state.items)[0].shape[0]
    return jnp.arange(cap) < state.count, state.count
