"""repro.core -- the paper's contribution: temporally-biased sampling schemes.

JAX (fixed-shape, jit/scan/shard_map-safe) implementations:
  * :mod:`repro.core.api`     -- the unified Sampler protocol + string registry
  * :mod:`repro.core.rtbs`    -- R-TBS (Algorithm 2+3), the paper's main algorithm
  * :mod:`repro.core.simple`  -- T-TBS (Alg. 1), B-TBS (Alg. 4), B-RS (Alg. 5), SW
  * :mod:`repro.core.latent`  -- latent fractional samples + downsampling (Alg. 3)
  * :mod:`repro.core.rng`     -- exact binomial/hypergeometric/stochastic-rounding
  * :mod:`repro.core.distributed` -- D-R-TBS / D-T-TBS over shard_map (Sec. 5)

Paper-literal Python oracles (incl. B-Chao, Appendix D): :mod:`repro.core.ref`.
"""
from . import api, latent, ref, rng, rtbs, simple  # noqa: F401
from .api import SampleView, Sampler, available_schemes, make_sampler  # noqa: F401
from .latent import Latent, downsample, realize  # noqa: F401
from .rtbs import RTBSState, init as rtbs_init, step as rtbs_step  # noqa: F401
