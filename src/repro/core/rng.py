"""Random-variate primitives used by the TBS family of algorithms.

Everything here is exact (inverse-transform / conditional decompositions), jit-safe
(fixed trip counts), and scalar-cheap: these are the per-batch bookkeeping draws of
Algorithms 1/2/3/5 of the paper, not per-item work.

The paper [Hentschel, Haas, Tian 2018] relies on three primitives:
  * BINOMIAL(j, r)           -- Alg. 1 lines 6/8  (T-TBS thinning)
  * HYPERGEO(k, a, b)        -- Alg. 5 line 5     (B-RS), and the multivariate split
                                used by D-R-TBS "distributed decisions" (Sec. 5.3)
  * STOCHROUND(x)            -- Alg. 2 line 16    (R-TBS saturated inserts)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln


def binomial(key: jax.Array, n, p) -> jax.Array:
    """Exact Binomial(n, p) draw (int32). `n` may be a traced int array."""
    n = jnp.asarray(n, jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    # jax.random.binomial handles n==0 / p in {0,1} correctly and is exact.
    draw = jax.random.binomial(key, n, jnp.clip(p, 0.0, 1.0))
    return draw.astype(jnp.int32)


def stochastic_round(key: jax.Array, x) -> jax.Array:
    """StochRound(x): floor(x) + Bernoulli(frac(x)); E[result] == x (paper Sec. 4.1)."""
    x = jnp.asarray(x, jnp.float32)
    lo = jnp.floor(x)
    up = jax.random.bernoulli(key, jnp.clip(x - lo, 0.0, 1.0))
    return (lo + up).astype(jnp.int32)


def _log_comb(n, k):
    """log C(n, k); requires 0 <= k <= n elementwise (caller guards)."""
    return gammaln(n + 1.0) - gammaln(k + 1.0) - gammaln(n - k + 1.0)


def hypergeometric(key: jax.Array, k, a, b, *, max_support: int) -> jax.Array:
    """Exact HyperGeo(k, a, b) draw: number of type-`a` items when drawing `k`
    without replacement from a population of `a` type-a and `b` type-b items.

    Inverse-transform over the support [max(0, k-b), min(a, k)] using the pmf
    ratio recurrence; `max_support` is a static bound on the support width
    (use the reservoir/batch capacity). O(max_support) scalar flops.
    """
    k = jnp.asarray(k, jnp.float32)
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    lo = jnp.maximum(0.0, k - b)
    hi = jnp.minimum(a, k)
    u = jax.random.uniform(key, dtype=jnp.float32)
    logp0 = _log_comb(a, lo) + _log_comb(b, k - lo) - _log_comb(a + b, k)

    def body(i, carry):
        cdf, logp, val = carry
        s = lo + i
        in_support = s <= hi
        cdf = cdf + jnp.where(in_support, jnp.exp(logp), 0.0)
        take = (cdf >= u) & (val < 0) & in_support
        val = jnp.where(take, s, val)
        # pmf ratio p(s+1)/p(s) = (a-s)(k-s) / ((s+1)(b-k+s+1))
        num = (a - s) * (k - s)
        den = (s + 1.0) * (b - k + s + 1.0)
        ratio = jnp.where((num > 0) & (den > 0), num / den, 1.0)
        logp = logp + jnp.log(ratio)
        return cdf, logp, val

    _, _, val = jax.lax.fori_loop(
        0, max_support + 1, body, (jnp.float32(0.0), logp0, jnp.float32(-1.0))
    )
    # Numerical guard: if float32 cdf never crossed u (prob ~1e-6), return hi.
    val = jnp.where(val < 0, hi, val)
    return val.astype(jnp.int32)


def multivariate_hypergeometric(
    key: jax.Array, k, counts: jax.Array, *, max_support: int
) -> jax.Array:
    """Exact multivariate hypergeometric split: draw `k` items without replacement
    from groups of sizes ``counts[s]``; return per-group draw counts.

    This is the primitive behind D-R-TBS *distributed decisions* (paper Sec. 5.3):
    the number of deletes/inserts assigned to each reservoir/batch partition.
    Decomposed as a chain of conditional (univariate) hypergeometrics; every
    shard computes the identical split from the same key.
    """
    counts = jnp.asarray(counts, jnp.int32)
    total = jnp.sum(counts)
    k = jnp.asarray(k, jnp.int32)

    def step(carry, inp):
        rem_draws, rem_total = carry
        c_s, key_s = inp
        other = rem_total - c_s
        x = hypergeometric(key_s, rem_draws, c_s, other, max_support=max_support)
        return (rem_draws - x, other), x

    keys = jax.random.split(key, counts.shape[0])
    (_, _), xs = jax.lax.scan(step, (k, total), (counts, keys))
    return xs


def prefix_permutation(key: jax.Array, cap: int, n) -> jax.Array:
    """Return an index array idx[cap] whose first `n` entries are a uniform random
    permutation of {0..n-1} (the valid prefix); entries >= n are the remaining
    slots in ascending order. `n` may be traced.

    This is the fixed-shape equivalent of the paper's SAMPLE(A, m): take
    ``idx[:m]`` for a uniform m-subset (in uniform random order) of the n
    valid slots.

    O(cap log cap) argsort formulation -- kept as the exact reference; the hot
    paths use the argsort-free :func:`prefix_permutation_fast`.
    """
    u = jax.random.uniform(key, (cap,), dtype=jnp.float32)
    slot = jnp.arange(cap, dtype=jnp.int32)
    sort_key = jnp.where(slot < n, u, 2.0 + slot.astype(jnp.float32))
    return jnp.argsort(sort_key).astype(jnp.int32)


_SON_M1 = jnp.uint32(0x85EBCA6B)   # murmur3 mixing constant
_SON_BIT = jnp.uint32(0x10000)     # swap decision: bit 16 of the mixed hash

#: default swap-or-not round count. HMR need O(log n) rounds for full CCA
#: security; the statistical invariants we rely on (k-point inclusion
#: marginals) mix much faster -- at 16 rounds the empirical bias is below
#: Monte-Carlo noise (< 3e-3 at 2e5 trials) even on 3-element domains
#: (tests/test_tbs_step.py re-measures this).
SON_ROUNDS = 16


def swap_or_not(key: jax.Array, x: jax.Array, n, *, rounds: int = SON_ROUNDS) -> jax.Array:
    """Evaluate a keyed pseudorandom permutation pi of {0..n-1} at the points
    ``x`` (int32 array, entries in [0, n)) via the swap-or-not shuffle
    [Hoang, Morris, Rogaway 2012]. `n` may be traced; `rounds` is static.

    Each round draws a uniform offset K_r and reflects x -> K_r - x (mod n)
    when a keyed hash bit of the {x, partner} pair fires; the composition is a
    bijection on [0, n) evaluable pointwise in O(rounds) int ops per element --
    no sort, no O(n) state, CPU-dispatch-lean (the round body is 8 fused
    elementwise ops; the single integer division is hoisted out of the loop).
    This is an *approximately* uniform permutation (a PRP, not an exact
    Fisher-Yates draw); DESIGN.md Sec. 11 records the RNG-stream implications.
    """
    n = jnp.asarray(n, jnp.int32)
    nn = jnp.maximum(n, 1)
    rb = jax.random.bits(key, (rounds, 2), jnp.uint32)
    k_all = (rb[:, 0] % nn.astype(jnp.uint32)).astype(jnp.int32)  # [rounds]
    for r in range(rounds):
        partner = k_all[r] - x                       # in (-n, n)
        partner = jnp.where(partner < 0, partner + nn, partner)
        h = jnp.maximum(x, partner).astype(jnp.uint32) * _SON_M1 + rb[r, 1]
        x = jnp.where((h & _SON_BIT) != 0, partner, x)
    return x


def prefix_permutation_fast(
    key: jax.Array, cap: int, n, *, k: int | None = None, rounds: int = SON_ROUNDS
) -> jax.Array:
    """Argsort-free :func:`prefix_permutation`: idx[k] whose entries at
    positions i < n are pi(i) for a keyed pseudorandom permutation pi of
    {0..n-1}, and identity (the remaining slots in ascending order) above.

    ``k`` (static, default ``cap``) is the consumed prefix length: victim
    selection needs only ``m`` entries, batch picks only ``bcount``, so
    callers that consume a short prefix pass ``k`` and pay O(k), not O(cap).
    Same structural contract as :func:`prefix_permutation` (first-n entries a
    permutation of {0..n-1}, ascending remainder); the permutation is a PRP
    rather than an exact uniform draw -- statistically indistinguishable at
    the tolerances of every Theorem 4.1/4.2 check (see tests/test_tbs_step.py).
    """
    k = cap if k is None else k
    n = jnp.asarray(n, jnp.int32)
    i = jnp.arange(k, dtype=jnp.int32)
    x = swap_or_not(key, jnp.minimum(i, jnp.maximum(n, 1) - 1), n, rounds=rounds)
    return jnp.where(i < n, x, i).astype(jnp.int32)


def categorical_from_counts(key: jax.Array, counts: jax.Array) -> jax.Array:
    """Sample index s with probability counts[s]/sum(counts) (counts int, >=0)."""
    c = jnp.asarray(counts, jnp.float32)
    tot = jnp.sum(c)
    u = jax.random.uniform(key) * jnp.maximum(tot, 1e-30)
    cdf = jnp.cumsum(c)
    return jnp.argmax(cdf > u).astype(jnp.int32)
