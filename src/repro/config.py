"""Configuration system: model / shape / mesh / run configs + the arch registry.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``;
``get_config(name)`` resolves them. Shapes (the assignment's per-arch input
shapes) are ``ShapeConfig``s; ``CELLS`` enumerates the full (arch x shape)
dry-run grid with the documented long_500k skips for pure full-attention archs
(DESIGN.md Sec. 5).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                 # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 32000
    # attention
    rope_theta: float = 1e6
    sliding_window: int = 0           # 0 -> full attention
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE half-dim sections
    norm_eps: float = 1e-5
    act: str = "swiglu"               # swiglu | gelu
    use_bias: bool = False
    tie_embeddings: bool = False
    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    # dispatch groups: routing/capacity is computed per group; set to the DP
    # shard count by the launcher so dispatch buffers stay batch-sharded
    moe_groups: int = 1
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # hybrid (zamba2-style): one shared attention block every `attn_every` layers
    attn_every: int = 0
    # encoder-decoder (whisper-style)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500           # stubbed conv-frontend output frames
    # modality frontend stubs (vlm/audio): inputs are precomputed embeddings
    embed_stub: bool = False
    # kv-head replication for decode caches when num_kv_heads < TP degree
    # (set by the launcher; repeats kv heads so the cache shards TP-ways)
    kv_replication: int = 1
    # online-softmax chunked attention threshold/block (0 = always-dense SDPA);
    # sequences >= this length use flash-style blocked attention
    attn_chunk: int = 8192
    # §Perf knobs (beyond-paper; baselines keep the defaults)
    cast_params_once: bool = False   # pre-cast params to bf16 before the layer
    #                                  stack: FSDP all-gathers move bf16 not f32
    fsdp_params: bool = True         # False = inference weight layout (TP-only,
    #                                  no per-step weight gathers for decode)
    # numerics / perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True                # remat each block in train fwd
    scan_layers: bool = True          # lax.scan over stacked layer params
    attention_impl: str = "xla"       # xla | pallas

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 for clean 2-axis sharding
        (standard practice; padding rows are never routed to)."""
        return _round_up(self.vocab_size, 256)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM state / hybrid /
        bounded sliding-window cache.)"""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding unpadded; used for 6ND)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            per = self._ssm_layer_params()
            return emb + self.num_layers * per + d  # final norm
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.act == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.num_experts:
            mlp = self.num_experts * mlp + d * self.num_experts  # + router
        norms = 2 * d
        per_layer = attn + mlp + norms
        if self.family == "hybrid":
            n_attn = self.num_layers // max(self.attn_every, 1)
            per_ssm = self._ssm_layer_params()
            return emb + self.num_layers * per_ssm + 1 * (attn + 2 * d * self.d_ff) + d
        total = emb + self.num_layers * per_layer + d
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder adds cross-attn (already in
            # num_layers loop? no -- count decoder cross attn explicitly)
            enc = self.encoder_layers * (attn + mlp + norms)
            cross = self.num_layers * (attn + d)
            total += enc + cross
        return total

    def _ssm_layer_params(self) -> int:
        d, din, ns = self.d_model, self.ssm_d_inner, self.ssm_state
        g, h = self.ssm_groups, self.ssm_heads
        in_proj = d * (2 * din + 2 * g * ns + h)
        conv = (din + 2 * g * ns) * self.ssm_conv_width
        out = din * d
        return in_proj + conv + out + 2 * h + din + d

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top-k of experts) for 6*N_active*D."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        mlp_all = self.num_experts * (3 if self.act == "swiglu" else 2) * d * self.d_ff
        mlp_act = self.num_experts_per_tok * (3 if self.act == "swiglu" else 2) * d * self.d_ff
        return self.param_count() - self.num_layers * (mlp_all - mlp_act)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # train | prefill | decode
    microbatches: int = 1   # gradient-accumulation factor for train shapes


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "qwen2_vl_2b",
    "zamba2_2p7b",
    "granite_moe_3b",
    "mixtral_8x22b",
    "mamba2_370m",
    "granite_20b",
    "command_r_35b",
    "stablelm_12b",
    "mistral_large_123b",
    "whisper_large_v3",
]

# external-name -> module-name aliases (assignment ids use dashes/dots)
ALIASES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "zamba2-2.7b": "zamba2_2p7b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mamba2-370m": "mamba2_370m",
    "granite-20b": "granite_20b",
    "command-r-35b": "command_r_35b",
    "stablelm-12b": "stablelm_12b",
    "mistral-large-123b": "mistral_large_123b",
    "whisper-large-v3": "whisper_large_v3",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod_name = ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def cells(include_skipped: bool = False):
    """The assignment's (arch x shape) grid. Yields (arch_id, shape_name,
    skip_reason|None). long_500k is skipped for pure full-attention archs and
    decode shapes are kept for all (every assigned arch autoregressively
    decodes; whisper decodes with its decoder)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            skip = None
            if shape.name == "long_500k" and not cfg.subquadratic:
                skip = "full attention: 500k KV decode is infeasible (DESIGN.md §5)"
            if skip is None or include_skipped:
                yield arch, shape.name, skip
