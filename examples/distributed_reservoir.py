"""D-R-TBS on a multi-device mesh: the co-partitioned reservoir with
distributed decisions (paper Sec. 5.3, Fig. 6(b)) running over 8 host devices.

This script re-execs itself with XLA_FLAGS so the devices exist before jax
initializes (the same pattern the production launcher uses per-pod).

Run: PYTHONPATH=src python examples/distributed_reservoir.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import distributed as dist  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402

S, CAP_S, BPS, N, LAM = 8, 64, 16, 100, 0.1

mesh = make_mesh((S,), (dist.AXIS,))
step = functools.partial(dist.drtbs_shard_step, n=N, lam=LAM)


def shard_fn(key, items, nfull, partial, weight, tweight, oflow, bi, bc):
    st = dist.DRTBSShard(items=items, nfull=nfull[0], partial_item=partial,
                         weight=weight, total_weight=tweight, overflow=oflow[0])
    st = step(key, st, bi, bc[0])
    return (st.items, st.nfull[None], st.partial_item, st.weight,
            st.total_weight, st.overflow[None])


smapped = jax.jit(dist.shard_map(
    shard_fn, mesh=mesh,
    in_specs=(P(), P(dist.AXIS), P(dist.AXIS), P(), P(), P(), P(dist.AXIS),
              P(dist.AXIS), P(dist.AXIS)),
    out_specs=(P(dist.AXIS), P(dist.AXIS), P(), P(), P(), P(dist.AXIS)),
))

state = (
    jnp.zeros((S * CAP_S,), jnp.int32),   # items (ids)
    jnp.zeros((S,), jnp.int32),           # per-shard full counts
    jnp.int32(0),                         # replicated partial item
    jnp.float32(0.0),                     # C
    jnp.float32(0.0),                     # W
    jnp.zeros((S,), jnp.int32),           # overflow
)

print(f"mesh: {S} shards; global reservoir n={N}; uneven per-shard batches")
for t in range(12):
    bc = jnp.asarray([(t + s) % 3 * BPS // 2 for s in range(S)], jnp.int32)
    bi = jnp.arange(S * BPS, dtype=jnp.int32) + 10000 * t
    key = jax.random.fold_in(jax.random.key(0), t)
    state = smapped(key, *state, bi, bc)
    items, nfull, partial, weight, tweight, oflow = state
    print(f"  t={t:2d} |B|={int(bc.sum()):4d}  C={float(weight):6.2f}  "
          f"W={float(tweight):8.2f}  shard fulls={[int(x) for x in nfull]}")
assert int(oflow.sum()) == 0
print("bounded, co-partitioned, zero payload shuffling -- done.")
