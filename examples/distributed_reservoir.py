"""D-R-TBS on a multi-device mesh: the co-partitioned reservoir with
distributed decisions (paper Sec. 5.3, Fig. 6(b)) driving the paper's FULL
model-management loop over 8 host devices -- stream -> per-shard sample
update -> periodic retrain on the realized global sample -> prequential eval,
fused into one compiled program by :func:`repro.manage.make_sharded_run_loop`
(DESIGN.md Sec. 10).

This script re-execs itself with XLA_FLAGS so the devices exist before jax
initializes (the same pattern the production launcher uses per-pod).

Run: PYTHONPATH=src python examples/distributed_reservoir.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.api import make_sampler  # noqa: E402
from repro.data.streams import LinRegStream, mode_schedule  # noqa: E402
from repro.launch.mesh import make_data_mesh  # noqa: E402
from repro.manage import (  # noqa: E402
    make_model,
    make_sharded_run_loop,
    materialize_stream,
    shard_stream,
)

S, T, B, N, LAM = 8, 24, 64, 100, 0.1

# one global stream, co-partitioned: shard s owns slots [s*bcap_s, (s+1)*bcap_s)
batches, bcounts = materialize_stream(
    LinRegStream(seed=0), T, batch_size=B,
    mode=lambda t: mode_schedule("single", t),
)
batches, bcounts = shard_stream(batches, bcounts, S)

mesh = make_data_mesh(S)
sampler = make_sampler("drtbs", n=N, lam=LAM, cap_s=N + B)
model = make_model("linreg", dim=2)
run = make_sharded_run_loop(sampler, model, mesh, retrain_every=2)

print(f"mesh: {S} shards; global reservoir n={N}; fused scan over {T} ticks")
state, params, trace = run(jax.random.key(0), batches, bcounts)

metric = np.asarray(trace["metric"])
size = np.asarray(trace["size"])
for t in range(T):
    print(f"  t={t:2d} mse={metric[t]:7.3f}  |S|={int(size[t]):3d}")
print(f"final shard fulls={[int(x) for x in np.asarray(state.nfull)]}  "
      f"C={float(np.asarray(state.weight)[0]):.2f}  "
      f"W={float(np.asarray(state.total_weight)[0]):.2f}")
assert int(np.asarray(state.overflow).sum()) == 0
assert (size <= N).all()
print("bounded, co-partitioned, one fused program -- done.")
