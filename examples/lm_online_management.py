"""End-to-end driver example: keep a language model fresh on a drifting token
stream by periodically retraining on the R-TBS sample (the paper's loop,
lifted to the LM zoo).

Uses the reduced stablelm-family config so it runs on CPU in ~2 minutes; pass
--preset full --arch <id> on a real pod. The run prints prequential eval loss
around two drift events: watch it spike at the mode flips and recover after
the next retraining. The sampler is swappable: try ``--scheme sw`` or
``--scheme brs`` to see the time-biased sample's advantage disappear.

Run: PYTHONPATH=src python examples/lm_online_management.py
"""
from repro.launch.train import main

if __name__ == "__main__":
    log = main([
        "--arch", "stablelm_12b",
        "--scheme", "rtbs",
        "--preset", "smoke",
        "--ticks", "24",
        "--batch-per-tick", "24",
        "--reservoir", "128",
        "--lam", "0.15",
        "--seq-len", "48",
        "--retrain-every", "3",
        "--retrain-steps", "8",
        "--train-batch", "12",
        "--drift", "periodic",
    ])
    pre = [r["eval_loss"] for r in log[:3]]
    post = [r["eval_loss"] for r in log[-3:]]
    print(f"\nmean eval loss: first 3 ticks {sum(pre)/3:.3f} -> "
          f"last 3 ticks {sum(post)/3:.3f}")
