"""Quickstart: temporally-biased sampling + online model management in five
minutes, on the unified Sampler API.

1. ``make_sampler``: every scheme (R-TBS, T-TBS, B-TBS, Unif, SW) behind one
   ``init / step / extract`` interface -- swap schemes by changing a string.
2. Watch R-TBS inclusion probabilities decay at exactly e^{-lambda * age}.
3. ``repro.manage``: the paper's full stream -> sample -> retrain -> eval
   loop as ONE jit-compiled ``lax.scan``, run for two schemes x two models.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import make_sampler
from repro.data.streams import LinRegStream, UsenetLikeStream, mode_schedule
from repro.manage import make_model, make_run_loop, materialize_stream

# ---------------------------------------------------------------------------
print("== 1. one interface, every scheme ==")
PROTO = jax.ShapeDtypeStruct((), jnp.int32)
batch_sizes = [5, 80, 0, 0, 33, 7, 120, 1, 0, 64]
for scheme, kw in [("rtbs", dict(n=50, lam=0.2)),
                   ("brs", dict(n=50)),
                   ("sw", dict(n=50)),
                   ("ttbs", dict(n=50, lam=0.2, batch_size=35))]:
    sampler = make_sampler(scheme, **kw)
    state = sampler.init(PROTO)
    step = jax.jit(sampler.step)
    for t, b in enumerate(batch_sizes):
        items = jnp.full((128,), 1000 * (t + 1), jnp.int32) + jnp.arange(128)
        state = step(jax.random.fold_in(jax.random.key(0), t), state,
                     items, jnp.int32(b))
    view = sampler.extract(jax.random.key(99), state)
    print(f"  {scheme:5s} after {sum(batch_sizes)} items: |S| = {int(view.size)}")

# ---------------------------------------------------------------------------
print("\n== 2. empirical inclusion probabilities obey eq. (1) ==")
T, trials, n, lam = 6, 3000, 10, 0.35
sampler = make_sampler("rtbs", n=n, lam=lam)
probs = np.zeros(T)
for s in range(trials):
    st = sampler.init(PROTO)
    for t in range(T):
        items = jnp.full((8,), 1000 * (t + 1), jnp.int32) + jnp.arange(8)
        st = sampler.step(jax.random.fold_in(jax.random.key(s), t), st,
                          items, jnp.int32(5))
    view = sampler.extract(jax.random.fold_in(jax.random.key(s), 99), st)
    ages = T - np.asarray(view.items) // 1000  # age 0 = newest batch
    for a in range(T):
        probs[a] += float(((ages == a) & np.asarray(view.mask)).sum()) / 5
probs /= trials
print("  age  Pr[in sample]  Pr[age]/Pr[age-1]  (target e^-lambda = %.3f)"
      % np.exp(-lam))
for a in range(T):
    r = probs[a] / max(probs[a - 1], 1e-9) if a else float("nan")
    print(f"  {a:3d}  {probs[a]:.3f}          {r:5.3f}")

# ---------------------------------------------------------------------------
print("\n== 3. online model management: one fused scan, any scheme x model ==")
T = 40
lin_batches, lin_counts = materialize_stream(
    LinRegStream(seed=0), T, batch_size=100,
    mode=lambda t: mode_schedule("single", t, start=20, stop=30))
use = UsenetLikeStream(seed=0)
nb_batches, nb_counts = materialize_stream(use, T, batch_size=50)

runs = [
    ("rtbs", dict(n=300, lam=0.1), "linreg", dict(dim=2),
     (lin_batches, lin_counts), "mse"),
    ("sw", dict(n=300), "linreg", dict(dim=2),
     (lin_batches, lin_counts), "mse"),
    ("rtbs", dict(n=300, lam=0.3), "naive_bayes", dict(vocab=use.vocab),
     (nb_batches, nb_counts), "miss"),
    ("brs", dict(n=300), "naive_bayes", dict(vocab=use.vocab),
     (nb_batches, nb_counts), "miss"),
]
for scheme, skw, model_name, mkw, (batches, bcounts), unit in runs:
    run = make_run_loop(make_sampler(scheme, **skw), make_model(model_name, **mkw))
    _, _, trace = run(jax.random.key(7), batches, bcounts)   # ONE jitted scan
    m = np.asarray(trace["metric"])
    mid = m[T // 2 - 3: T // 2 + 3].mean()  # around the drift window
    print(f"  {scheme:5s} + {model_name:11s} {unit}: start {m[1:6].mean():6.3f}"
          f"  drift {mid:6.3f}  end {m[-5:].mean():6.3f}"
          f"  (avg |S| {np.asarray(trace['size']).mean():.0f})")
print("done: the paper's headline loop, compiled end-to-end. Swap schemes and\n"
      "models by changing the strings above; the paper's robustness claims\n"
      "emerge at full scale (PYTHONPATH=src python -m benchmarks.run fig12 fig13).")
