"""Quickstart: temporally-biased sampling in five minutes.

1. Maintain an R-TBS sample over a bursty stream -- bounded size, exact
   exponential time-biasing (paper Theorem 4.2).
2. Watch the inclusion probabilities decay at exactly e^{-lambda * age}.
3. Use the sample to keep a kNN classifier fresh under concept drift.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latent as lt
from repro.core import rtbs
from repro.data.streams import GMMStream, mode_schedule
from repro.models.simple_ml import knn_predict

# ---------------------------------------------------------------------------
print("== 1. bounded, time-biased sampling over a bursty stream ==")
n, lam = 50, 0.2
state = rtbs.init(jax.ShapeDtypeStruct((), jnp.int32), n)
batch_sizes = [5, 80, 0, 0, 33, 7, 120, 1, 0, 64]
for t, b in enumerate(batch_sizes):
    items = jnp.full((128,), 1000 * (t + 1), jnp.int32) + jnp.arange(128)
    state = rtbs.step(
        jax.random.fold_in(jax.random.key(0), t), state, items, jnp.int32(b),
        n=n, lam=lam,
    )
    print(f"  t={t}: batch={b:4d}  sample weight C={float(state.lat.weight):6.2f}"
          f"  total weight W={float(state.total_weight):8.2f}  (bound n={n})")

# ---------------------------------------------------------------------------
print("\n== 2. empirical inclusion probabilities obey eq. (1) ==")
T, trials = 6, 3000
probs = np.zeros(T)
for s in range(trials):
    st = rtbs.init(jax.ShapeDtypeStruct((), jnp.int32), 10)
    for t in range(T):
        items = jnp.full((8,), 1000 * (t + 1), jnp.int32) + jnp.arange(8)
        st = rtbs.step(jax.random.fold_in(jax.random.key(s), t), st, items,
                       jnp.int32(5), n=10, lam=0.35)
    mask, _ = lt.realize(jax.random.fold_in(jax.random.key(s), 99), st.lat)
    ages = T - np.asarray(st.lat.items) // 1000  # age 0 = newest batch
    for a in range(T):
        probs[a] += float(((ages == a) & np.asarray(mask)).sum()) / 5
probs /= trials
print("  age  Pr[in sample]  Pr[age]/Pr[age-1]  (target e^-lambda = %.3f)"
      % np.exp(-0.35))
for a in range(T):
    r = probs[a] / max(probs[a - 1], 1e-9) if a else float("nan")
    print(f"  {a:3d}  {probs[a]:.3f}          {r:5.3f}")

# ---------------------------------------------------------------------------
print("\n== 3. online model management: kNN under concept drift ==")
ITEM = {"x": jax.ShapeDtypeStruct((2,), jnp.float32),
        "y": jax.ShapeDtypeStruct((), jnp.int32)}
g = GMMStream(seed=0)
st = rtbs.init(ITEM, 300)
for t in range(40):
    mode = mode_schedule("single", t, start=20, stop=30)
    x, y = g.batch(t, 100, mode)
    key = jax.random.fold_in(jax.random.key(7), t)
    if t >= 10:
        mask, _ = rtbs.realize(jax.random.fold_in(key, 1), st)
        pred = knn_predict(st.lat.items["x"], st.lat.items["y"], mask,
                           jnp.asarray(x), k=7, num_classes=100)
        err = float((np.asarray(pred) != y).mean()) * 100
        marker = " <-- drift!" if mode else ""
        if t % 4 == 0 or mode:
            print(f"  t={t:3d} mode={mode} miss={err:5.1f}%{marker}")
    st = rtbs.step(key, st, {"x": jnp.asarray(x), "y": jnp.asarray(y)},
                   jnp.int32(100), n=300, lam=0.1)
print("done: the retrained-on-sample model adapts to the drift and recovers.")
