"""Batched serving example: prefill a batch of prompts through the Mamba2
(attention-free) model and decode greedily -- O(1) state per sequence, so the
same code path scales to the long_500k cell on real hardware.

Run: PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main([
        "--arch", "mamba2_370m",
        "--preset", "smoke",
        "--prompts", "4",
        "--prompt-len", "16",
        "--gen", "12",
    ])
