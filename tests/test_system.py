"""End-to-end system tests: online-model-management driver, checkpoint/restart
(bit-exact resume), straggler-tolerant pipeline, elastic reservoir resharding,
simple-ML models on the paper's streams."""
import numpy as np


def test_driver_runs_and_adapts(tmp_path):
    """The full loop (stream -> R-TBS -> periodic retraining) runs and the
    retrained model improves on the stream it samples from."""
    from repro.launch.train import main

    log = main([
        "--arch", "mamba2_370m", "--preset", "smoke", "--ticks", "12",
        "--batch-per-tick", "24", "--reservoir", "96", "--retrain-every", "3",
        "--retrain-steps", "6", "--train-batch", "8", "--drift", "none",
        "--seq-len", "32",
    ])
    assert len(log) == 12
    first, last = log[0]["eval_loss"], log[-1]["eval_loss"]
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first, (first, last)  # learned something


def test_checkpoint_restart_bit_exact(tmp_path):
    """Kill/restart fault-tolerance contract: resuming from a checkpoint
    reproduces exactly the run that never stopped."""
    from repro.launch.train import main

    base = [
        "--arch", "stablelm_12b", "--preset", "smoke", "--batch-per-tick", "16",
        "--reservoir", "64", "--retrain-every", "2", "--retrain-steps", "2",
        "--train-batch", "8", "--seq-len", "32", "--ckpt-every", "4",
    ]
    full = main(base + ["--ticks", "8", "--ckpt-dir", str(tmp_path / "a")])
    # interrupted run: stop at 4, resume to 8
    main(base + ["--ticks", "4", "--ckpt-dir", str(tmp_path / "b")])
    resumed = main(base + ["--ticks", "8", "--ckpt-dir", str(tmp_path / "b"),
                           "--resume"])
    f = {r["tick"]: r for r in full}
    for r in resumed:
        t = r["tick"]
        assert abs(r["eval_loss"] - f[t]["eval_loss"]) < 1e-5, (t, r, f[t])
        assert abs(r["total_weight"] - f[t]["total_weight"]) < 1e-3


def test_pipeline_straggler_tolerance():
    """A stalled shard contributes zero items that tick; the tick still
    completes and the data arrives next tick (counts conserved)."""
    import time

    from repro.data.pipeline import StreamPipeline

    delay = {"on": True}

    def make_batch(t, shard):
        if shard == 1 and t == 0 and delay["on"]:
            time.sleep(1.0)
        return np.full((4, 2), t * 10 + shard, np.float32)

    pipe = StreamPipeline(
        make_batch, num_shards=3, shard_capacity=8, item_shape=(2,),
        tick_timeout=0.3,
    )
    items, counts = pipe.next_tick()
    assert counts[0] == 4 and counts[2] == 4
    assert counts[1] == 0  # straggler contributed nothing
    assert pipe.stats["late_shards"] == 1
    # once the stall clears, the late shard catches up
    time.sleep(1.2)
    items, counts = pipe.next_tick()
    assert counts[1] == 4
    pipe.close()


def test_elastic_reservoir_reshard():
    from repro.checkpoint import reshard_reservoir

    items = np.zeros((4, 8, 3), np.int32)
    nfull = np.array([5, 2, 0, 7])
    vals = iter(range(1, 100))
    for s in range(4):
        for j in range(nfull[s]):
            items[s, j] = next(vals)
    out, counts = reshard_reservoir(items, nfull, new_shards=2, cap_s=16)
    assert counts.sum() == nfull.sum()
    got = sorted(
        tuple(out[s, j]) for s in range(2) for j in range(counts[s])
    )
    want = sorted(
        tuple(items[s, j]) for s in range(4) for j in range(nfull[s])
    )
    assert got == want


def test_checkpoint_atomicity(tmp_path):
    """A checkpoint dir either exists completely or not at all; pruning keeps
    the newest `keep`."""
    import jax.numpy as jnp

    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

    tree = {"a": jnp.arange(5), "b": (jnp.ones((2, 2)), jnp.int32(3))}
    for s in [1, 2, 3, 4]:
        save_checkpoint(tmp_path, s, tree, keep=2)
    assert latest_step(tmp_path) == 4
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_3", "step_4"]
    back = restore_checkpoint(tmp_path, 4, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(5))
    assert int(back["b"][1]) == 3


# ---------------------------------------------------------------------------
# simple-ML models on the paper's streams
# ---------------------------------------------------------------------------
def test_knn_on_gmm_stream():
    import jax.numpy as jnp

    from repro.data.streams import GMMStream
    from repro.models.simple_ml import knn_predict

    g = GMMStream(seed=0)
    x, y = g.batch(0, 400, 0)
    qx, qy = g.batch(1, 100, 0)
    pred = knn_predict(
        jnp.asarray(x), jnp.asarray(y), jnp.ones((400,), bool),
        jnp.asarray(qx), k=7, num_classes=100,
    )
    acc = float((np.asarray(pred) == qy).mean())
    assert acc > 0.6, acc  # paper's regime: ~18% error in-mode


def test_linreg_on_stream():
    import jax.numpy as jnp

    from repro.data.streams import LinRegStream
    from repro.models.simple_ml import linreg_fit, linreg_predict

    s = LinRegStream(seed=0)
    x, y = s.batch(0, 500, 0)
    coef = linreg_fit(jnp.asarray(x), jnp.asarray(y), jnp.ones((500,), bool))
    qx, qy = s.batch(1, 200, 0)
    mse = float(np.mean((np.asarray(linreg_predict(coef, jnp.asarray(qx))) - qy) ** 2))
    assert mse < 1.5, mse  # noise floor is 1.0


def test_nb_on_usenet_like():
    import jax.numpy as jnp

    from repro.data.streams import UsenetLikeStream
    from repro.models.simple_ml import nb_fit, nb_predict

    s = UsenetLikeStream(seed=0)
    x, y = s.batch(0, 290, 0)   # within one context window
    params = nb_fit(jnp.asarray(x), jnp.asarray(y), jnp.ones((290,), bool))
    qx, qy = s.batch(0, 290, 0)
    acc = float((np.asarray(nb_predict(params, jnp.asarray(qx))) == qy).mean())
    assert acc > 0.9, acc
