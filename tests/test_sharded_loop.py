"""Sharded manage loop (paper Sec. 5 schemes driving the Sec. 6 harness;
DESIGN.md Sec. 10):

  * fused shard_map scan == unfused per-tick shard_map driver, bit-exactly,
    on a 1-shard mesh (every pytest run) and on a real 8-device mesh
    (subprocess, below)
  * builder memoization and local/distributed scheme guards
  * the 8-virtual-device D-R-TBS FARM statistics check (Theorem 4.2 on the
    final reservoir of every Monte-Carlo trial, W/C trajectories, size
    bounds, fractional-item materialization through extract_global) runs in
    a subprocess so the main pytest process keeps its default device count.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import make_sampler
from repro.data.streams import LinRegStream
from repro.launch.mesh import make_data_mesh
from repro.manage import (
    init_sharded_state,
    make_model,
    make_sharded_manage_step,
    make_sharded_resume_loop,
    make_sharded_run_farm,
    make_sharded_run_loop,
    materialize_stream,
    shard_stream,
)

HERE = pathlib.Path(__file__).parent
SRC = str(HERE.parent / "src")

SHARDED = {
    "drtbs": dict(n=24, lam=0.2, cap_s=64),
    "dttbs": dict(n=12, lam=0.2, batch_size=12),
}


def _stream(T=10, b=16, num_shards=1):
    batches, bcounts = materialize_stream(LinRegStream(seed=0), T,
                                          batch_size=b)
    return shard_stream(batches, bcounts, num_shards)


@pytest.mark.parametrize("scheme", sorted(SHARDED))
def test_fused_matches_per_tick_driver_one_shard(scheme):
    """On a 1-shard mesh the fused scan must be bit-identical to driving
    make_sharded_manage_step tick by tick with the same tick_keys."""
    T = 10
    sampler = make_sampler(scheme, **SHARDED[scheme])
    model = make_model("linreg", dim=2)
    batches, bcounts = _stream(T=T, num_shards=1)
    mesh = make_data_mesh(1)
    key = jax.random.key(42)

    run = make_sharded_run_loop(sampler, model, mesh, retrain_every=2)
    state_f, params_f, trace = run(key, batches, bcounts)

    tick = make_sharded_manage_step(sampler, model, mesh, retrain_every=2)
    proto = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[2:], a.dtype), batches
    )
    state = init_sharded_state(sampler, 1, proto)
    params = model.init()
    metrics, sizes = [], []
    for t in range(T):
        bt = jax.tree_util.tree_map(lambda a: a[t], batches)
        state, params, m = tick(key, jnp.int32(t), state, params, bt,
                                bcounts[t])
        metrics.append(np.asarray(m["metric"]))
        sizes.append(np.asarray(m["size"]))

    np.testing.assert_array_equal(np.asarray(trace["metric"]),
                                  np.asarray(metrics))
    np.testing.assert_array_equal(np.asarray(trace["size"]),
                                  np.asarray(sizes))
    for a, b in zip(jax.tree_util.tree_leaves(state_f),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(params_f), np.asarray(params))


def test_sharded_loop_trains_and_bounds_size():
    sampler = make_sampler("drtbs", n=24, lam=0.1, cap_s=64)
    model = make_model("linreg", dim=2)
    batches, bcounts = _stream(T=16, b=20, num_shards=jax.device_count())
    mesh = make_data_mesh(jax.device_count())
    run = make_sharded_run_loop(sampler, model, mesh)
    state, params, trace = run(jax.random.key(0), batches, bcounts)
    size = np.asarray(trace["size"])
    assert (size <= 24).all()
    assert int(np.asarray(state.overflow).sum()) == 0
    assert np.isfinite(np.asarray(trace["metric"])[1:]).all()


def test_sharded_farm_shapes_and_variation():
    S = jax.device_count()
    sampler = make_sampler("drtbs", n=16, lam=0.2, cap_s=48)
    model = make_model("linreg", dim=2)
    batches, bcounts = _stream(T=6, b=12, num_shards=S)
    mesh = make_data_mesh(S)
    farm = make_sharded_run_farm(sampler, model, mesh, retrain_every=2)
    states, params, trace = farm(jax.random.key(5), 4, batches, bcounts)
    assert trace["metric"].shape == (4, 6)
    assert states.nfull.shape == (4, S)
    assert params.shape == (4, 3)
    # independent trials -> sampler randomness actually varies the reservoir
    items = np.asarray(states.items["x"]).reshape(4, -1)
    assert len({items[i].tobytes() for i in range(4)}) > 1


@pytest.mark.parametrize("scheme", sorted(SHARDED))
def test_sharded_resume_matches_unbroken_run(scheme):
    """Checkpoint/resume for the fused sharded run: consuming the stream in
    segments through make_sharded_resume_loop -- including a serialize/
    restore round-trip of the gather_tree snapshot between segments -- is
    bit-identical to the unbroken fused run (same key discipline via the
    global tick offset t0)."""
    import tempfile

    from repro.checkpoint import restore_checkpoint, save_checkpoint

    T, cut = 12, 4
    S = jax.device_count()
    sampler = make_sampler(scheme, **SHARDED[scheme])
    model = make_model("linreg", dim=2)
    batches, bcounts = _stream(T=T, num_shards=S)
    mesh = make_data_mesh(S)
    key = jax.random.key(17)

    full = make_sharded_run_loop(sampler, model, mesh, retrain_every=2)
    state_f, params_f, trace_f = full(key, batches, bcounts)

    resume = make_sharded_resume_loop(sampler, model, mesh, retrain_every=2)
    proto = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[2:], a.dtype), batches
    )
    state, params = init_sharded_state(sampler, S, proto), model.init()
    traces = []
    with tempfile.TemporaryDirectory() as d:
        for t0 in range(0, T, cut):
            seg = jax.tree_util.tree_map(lambda a: a[t0:t0 + cut], batches)
            state, params, tr = resume(key, state, params, seg,
                                       bcounts[t0:t0 + cut], t0)
            traces.append(tr)
            # durable round-trip: what launch/train.py serializes
            save_checkpoint(d, t0 + cut, (state, params, t0 + cut))
            state, params, _ = restore_checkpoint(
                d, t0 + cut, (state, params, 0)
            )
            state = jax.tree_util.tree_map(jnp.asarray, state)
            params = jax.tree_util.tree_map(jnp.asarray, params)

    for a, b in zip(jax.tree_util.tree_leaves((state_f, params_f)),
                    jax.tree_util.tree_leaves((state, params))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in trace_f:
        got = np.concatenate([np.asarray(t[k]) for t in traces])
        np.testing.assert_array_equal(np.asarray(trace_f[k]), got)
    # misaligned resume ticks fail fast instead of silently drifting the
    # retrain cadence
    with pytest.raises(ValueError, match="multiple of"):
        make_sharded_resume_loop(sampler, model, mesh, retrain_every=2,
                                 superbatch=2)(
            key, state, params, batches, bcounts, 3)


def test_controlled_fused_matches_controlled_per_tick_driver():
    """ROADMAP decay follow-up (c): make_sharded_manage_step threads
    ``controller=`` -- driving the controlled per-tick driver tick by tick
    (controller state round-tripped alongside the snapshot) is bit-identical
    to the fused controlled sharded loop."""
    from repro import decay as dk

    T = 8
    sampler = make_sampler("drtbs", n=24, lam=0.2, cap_s=64)
    model = make_model("linreg", dim=2)
    ctrl = dk.loss_ratio(lam0=0.2, lam_min=0.02, lam_max=1.0)
    batches, bcounts = _stream(T=T, num_shards=1)
    mesh = make_data_mesh(1)
    key = jax.random.key(4)

    run = make_sharded_run_loop(sampler, model, mesh, retrain_every=2,
                                controller=ctrl)
    state_f, params_f, trace = run(key, batches, bcounts)
    assert "decay" in trace

    tick = make_sharded_manage_step(sampler, model, mesh, retrain_every=2,
                                    controller=ctrl)
    assert tick is make_sharded_manage_step(sampler, model, mesh,
                                            retrain_every=2, controller=ctrl)
    assert tick is not make_sharded_manage_step(sampler, model, mesh,
                                                retrain_every=2)
    proto = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[2:], a.dtype), batches
    )
    state = init_sharded_state(sampler, 1, proto)
    params, cstate = model.init(), ctrl.init()
    rows = []
    for t in range(T):
        bt = jax.tree_util.tree_map(lambda a: a[t], batches)
        state, params, cstate, m = tick(key, jnp.int32(t), state, params,
                                        cstate, bt, bcounts[t])
        rows.append(m)
    for k in trace:
        got = np.stack([np.asarray(r[k]) for r in rows])
        np.testing.assert_array_equal(np.asarray(trace[k]), got)
    for a, b in zip(jax.tree_util.tree_leaves((state_f, params_f)),
                    jax.tree_util.tree_leaves((state, params))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the controller actually engaged: the decay trace is not constant-free
    assert np.asarray(trace["decay"]).shape == (T,)


def test_sharded_builders_memoized():
    sampler = make_sampler("drtbs", n=8, lam=0.2, cap_s=16)
    model = make_model("linreg", dim=2)
    mesh = make_data_mesh(1)
    r1 = make_sharded_run_loop(sampler, model, mesh)
    assert r1 is make_sharded_run_loop(sampler, model, mesh)
    assert r1 is not make_sharded_run_loop(sampler, model, mesh,
                                           retrain_every=2)
    t1 = make_sharded_manage_step(sampler, model, mesh)
    assert t1 is make_sharded_manage_step(sampler, model, mesh)
    f1 = make_sharded_run_farm(sampler, model, mesh)
    assert f1 is make_sharded_run_farm(sampler, model, mesh)


def test_sharded_loop_rejects_local_samplers():
    model = make_model("linreg", dim=2)
    mesh = make_data_mesh(1)
    for scheme, hyper in (("rtbs", dict(n=8, lam=0.1)), ("sw", dict(n=8))):
        s = make_sampler(scheme, **hyper)
        with pytest.raises(ValueError, match="local scheme"):
            make_sharded_run_loop(s, model, mesh)
        with pytest.raises(ValueError, match="local scheme"):
            make_sharded_manage_step(s, model, mesh)
        with pytest.raises(ValueError, match="local scheme"):
            make_sharded_run_farm(s, model, mesh)


def test_shard_stream_repacks_exactly():
    batches, bcounts = materialize_stream(LinRegStream(seed=1), 5,
                                          batch_size=lambda t: [7, 3, 0, 8, 5][t])
    sb, sc = shard_stream(batches, bcounts, 3)
    assert sc.shape == (5, 3)
    np.testing.assert_array_equal(np.asarray(sc).sum(axis=1),
                                  np.asarray(bcounts))
    bcap_s = sb["x"].shape[1] // 3
    # every valid global item appears exactly once, in shard-segment order
    for t in range(5):
        got = []
        for s in range(3):
            c = int(sc[t, s])
            got.append(np.asarray(sb["x"])[t, s * bcap_s:s * bcap_s + c])
        got = np.concatenate(got) if got else np.zeros((0, 2))
        np.testing.assert_array_equal(
            got, np.asarray(batches["x"])[t, : int(bcounts[t])]
        )


def _run_subprocess(script, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # the script sets its own device count
    proc = subprocess.run(
        [sys.executable, str(HERE / script)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_sharded_loop_8shard_farm_statistics():
    """Fused==per-tick at 8 shards + Theorem 4.2 over the Monte-Carlo farm
    on a real 8-device mesh (promoted from the style of
    tests/_drtbs_stat_check.py onto the fused sharded loop)."""
    out = _run_subprocess("_sharded_loop_check.py")
    assert "sharded-loop checks passed" in out
