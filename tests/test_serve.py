"""Serving-path telemetry: repro.launch.serve + examples/serve_batched.py.

The batched serving driver emits one ``kind="query"`` record per served
prompt through the same repro.obs sinks the manage loops drain into
(DESIGN.md Sec. 14). These tests run the driver at smoke size with an
injected in-memory Telemetry handle and assert the counters/records line
up with the prompts actually served.
"""
import importlib.util
import json
import pathlib
import sys

import pytest

from repro.launch import serve
from repro.obs import JsonlSink, MemorySink, Telemetry

REPO = pathlib.Path(__file__).resolve().parent.parent

ARGS = ["--arch", "mamba2_370m", "--preset", "smoke",
        "--prompts", "2", "--prompt-len", "4", "--gen", "2"]


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One serve run shared by the assertions below (prefill+decode compile
    once); drains into both a memory ring and a JSONL file."""
    path = tmp_path_factory.mktemp("serve") / "telemetry.jsonl"
    mem = MemorySink()
    tel = Telemetry([mem, JsonlSink(str(path))], monitors=())
    gen = serve.main(ARGS, telemetry=tel)
    return gen, tel, mem, path


def test_serve_counts_queries(served):
    gen, tel, mem, _ = served
    assert tel.queries == 2  # one query record per prompt
    queries = mem.by_kind("query")
    assert len(queries) == 2
    assert [q["query"] for q in queries] == [0, 1]


def test_serve_query_records_cumulative_tokens(served):
    gen, _, mem, _ = served
    queries = mem.by_kind("query")
    per_prompt = gen.shape[1]
    assert all(q["gen_tokens"] == per_prompt for q in queries)
    # tokens_served is cumulative across the batch
    assert [q["tokens_served"] for q in queries] == [per_prompt, 2 * per_prompt]
    for q in queries:
        assert q["prompt_len"] == 4
        assert q["prefill_s"] >= 0.0 and q["decode_s"] >= 0.0
        assert q["tok_per_s"] > 0.0


def test_serve_run_header(served):
    _, tel, mem, _ = served
    assert tel.runs == 1
    runs = mem.by_kind("run")
    assert len(runs) == 1
    hdr = runs[0]
    assert hdr["mode"] == "serve"
    assert hdr["arch"] == "mamba2_370m"
    assert hdr["prompts"] == 2 and hdr["gen"] == 2


def test_serve_jsonl_stream_valid(served):
    """The JSONL stream written by the injected sink passes the CI schema
    validator (benchmarks.check_telemetry)."""
    _, _, _, path = served
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks.check_telemetry import check_file
    finally:
        sys.path.pop(0)
    assert check_file(path) == []
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert lines[0]["kind"] == "run"
    assert sum(r["kind"] == "query" for r in lines) == 2


def test_serve_batched_example_wires_serve_main():
    """examples/serve_batched.py is a thin wrapper over the serving driver:
    importing it must not run anything, and its ``main`` must be the
    driver's (so the example inherits telemetry/profiling flags)."""
    path = REPO / "examples" / "serve_batched.py"
    spec = importlib.util.spec_from_file_location("serve_batched_example", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)  # __main__ guard keeps this import-only
    assert mod.main is serve.main
