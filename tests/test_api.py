"""Unified Sampler API + manage-loop contracts (DESIGN.md Sec. 8):

  * every registered scheme constructs and satisfies the protocol
  * init/step/extract round-trip under jit (local) / shard_map (distributed)
  * the fused manage loop is bit-identical to stepping the sampler directly
    with the documented key discipline
  * model adapters fit/evaluate on realized sample views
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed as dist
from repro.core.api import (
    SampleView,
    Sampler,
    available_schemes,
    make_sampler,
)
from repro.data.streams import LinRegStream, UsenetLikeStream
from repro.manage import (
    make_manage_step,
    make_model,
    make_run_farm,
    make_run_loop,
    make_sgd_adapter,
    materialize_stream,
    tick_keys,
)
from repro.manage.loop import item_proto

PROTO = jax.ShapeDtypeStruct((), jnp.int32)

LOCAL = {
    "rtbs": dict(n=10, lam=0.3),
    "ttbs": dict(n=10, lam=0.3, batch_size=8),
    "btbs": dict(lam=0.3, cap=64),
    "brs": dict(n=10),
    "sw": dict(n=10),
}
DISTRIBUTED = {
    "drtbs": dict(n=8, lam=0.3, cap_s=16),
    "dttbs": dict(n=4, lam=0.3, batch_size=4),
}


def _stream_ids(T=6, bcap=16, b=8):
    """Deterministic id stream: item id encodes its batch (1000*(t+1)+j)."""
    batches = np.zeros((T, bcap), np.int32)
    for t in range(T):
        batches[t, :b] = 1000 * (t + 1) + np.arange(b)
    return jnp.asarray(batches), jnp.full((T,), b, jnp.int32)


# ---------------------------------------------------------------------------
# registry + protocol
# ---------------------------------------------------------------------------
def test_registry_covers_all_schemes():
    assert set(available_schemes()) == set(LOCAL) | set(DISTRIBUTED)


def test_unknown_scheme_raises():
    with pytest.raises(ValueError, match="unknown sampling scheme"):
        make_sampler("nope")


def test_ttbs_rejects_invalid_q():
    with pytest.raises(ValueError, match="q ="):
        make_sampler("ttbs", n=100, lam=2.0, batch_size=1)  # q >> 1


@pytest.mark.parametrize("scheme", sorted(LOCAL) + sorted(DISTRIBUTED))
def test_protocol_shape(scheme):
    s = make_sampler(scheme, **{**LOCAL, **DISTRIBUTED}[scheme])
    assert isinstance(s, Sampler)
    assert s.scheme == scheme
    assert callable(s.init) and callable(s.step) and callable(s.extract)
    assert s.distributed == (scheme in DISTRIBUTED)
    assert dict(s.hyper)  # hyperparameters recorded


# ---------------------------------------------------------------------------
# local schemes: init/step/extract round-trip under jit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", sorted(LOCAL))
def test_local_roundtrip_under_jit(scheme):
    s = make_sampler(scheme, **LOCAL[scheme])
    batches, bcounts = _stream_ids()
    state = s.init(PROTO)
    step = jax.jit(s.step)
    for t in range(batches.shape[0]):
        state = step(jax.random.fold_in(jax.random.key(0), t), state,
                     batches[t], bcounts[t])
    view = jax.jit(s.extract)(jax.random.key(99), state)
    assert isinstance(view, SampleView)
    cap = view.mask.shape[0]
    assert jax.tree_util.tree_leaves(view.items)[0].shape[0] == cap
    assert int(view.size) == int(view.mask.sum())
    # every selected slot holds a genuinely streamed item id
    got = np.asarray(view.items)[np.asarray(view.mask)]
    assert got.size == int(view.size)
    assert ((got >= 1000) & (got < 1000 * 8)).all(), got


@pytest.mark.parametrize("scheme", sorted(LOCAL))
def test_local_step_scans(scheme):
    """The same step composes with lax.scan (fixed shapes end to end)."""
    s = make_sampler(scheme, **LOCAL[scheme])
    batches, bcounts = _stream_ids()
    keys = jax.random.split(jax.random.key(3), batches.shape[0])

    @jax.jit
    def run(batches, bcounts, keys):
        def body(state, inp):
            b, c, k = inp
            return s.step(k, state, b, c), None

        state, _ = jax.lax.scan(body, s.init(PROTO), (batches, bcounts, keys))
        return s.extract(jax.random.key(9), state)

    view = run(batches, bcounts, keys)
    assert int(view.size) == int(view.mask.sum())


@pytest.mark.parametrize("scheme", sorted(LOCAL))
def test_extract_mask_sum_equals_size_local(scheme):
    """Regression: an item counted in view.size must be materialized in the
    view (mask.sum() == size) for EVERY realization key -- R-TBS's fractional
    item is drawn per extract, so multiple keys hit both branches."""
    s = make_sampler(scheme, **LOCAL[scheme])
    batches, bcounts = _stream_ids()
    state = s.init(PROTO)
    for t in range(batches.shape[0]):
        state = s.step(jax.random.fold_in(jax.random.key(5), t), state,
                       batches[t], bcounts[t])
    for k in range(10):
        view = s.extract(jax.random.key(100 + k), state)
        assert int(view.mask.sum()) == int(view.size)
        assert int(s.size(jax.random.key(100 + k), state)) == int(view.size)


@pytest.mark.parametrize("scheme", sorted(DISTRIBUTED))
def test_extract_mask_sum_equals_size_distributed(scheme):
    """The dropped-fractional-item regression (D-R-TBS): the partial payload
    occupies the reserved slot whenever it is counted, per shard AND in the
    global view. Hyperparameters keep C fractional (unsaturated stream) so
    the partial-item branch is actually exercised."""
    from jax.sharding import PartitionSpec as P

    nsh = jax.device_count()
    hyper = dict(DISTRIBUTED[scheme])
    if scheme == "drtbs":
        # 3 ticks of 2 items/shard: W = 2*nsh*(d^2+d+1) < n at any mesh
        # width, so C = W keeps a fraction of ~0.6 and ~24 keys hit both
        # partial-item branches with overwhelming probability
        hyper.update(n=5 * nsh + 5, lam=0.3)
    s = make_sampler(scheme, **hyper)
    mesh = jax.make_mesh((nsh,), (dist.AXIS,))
    bcap_s = 8
    nkeys = 24

    def run(key, bitems, bcounts):
        state = s.init(PROTO)
        for t in range(3):
            state = s.step(jax.random.fold_in(key, t), state,
                           bitems[t], bcounts[t, 0])
        outs = []
        for k in range(nkeys):
            kk = jax.random.fold_in(key, 50 + k)
            view = s.extract(kk, state)
            gview = s.extract_global(kk, state)
            outs.append((view.mask, view.size[None],
                         s.size(kk, state)[None],
                         gview.mask, gview.size[None],
                         s.size_global(kk, state)[None]))
        return outs

    f = jax.jit(dist.shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(None, dist.AXIS), P(None, dist.AXIS)),
        out_specs=[(P(dist.AXIS), P(dist.AXIS), P(dist.AXIS),
                    P(), P(), P())] * nkeys,
    ))
    bitems = jnp.arange(3 * nsh * bcap_s, dtype=jnp.int32).reshape(
        3, nsh * bcap_s) + 1
    bcounts = jnp.full((3, nsh), 2, jnp.int32)
    sizes_seen = set()
    for mask, size_s, fast_s, gmask, gsize, gfast in f(jax.random.key(2),
                                                       bitems, bcounts):
        # per-shard: every counted item is selected by the mask
        assert int(mask.sum()) == int(size_s.sum()) == int(fast_s.sum())
        # global view: ditto, and it agrees with the per-shard realization
        assert int(gmask.sum()) == int(gsize[0]) == int(gfast[0])
        assert int(gsize[0]) == int(size_s.sum())
        sizes_seen.add(int(gsize[0]))
    if scheme == "drtbs":  # both partial-item branches must have been hit
        assert len(sizes_seen) == 2, sizes_seen


def test_bounded_schemes_respect_n():
    for scheme in ("rtbs", "brs", "sw"):
        s = make_sampler(scheme, **LOCAL[scheme])
        batches, bcounts = _stream_ids(T=8, bcap=32, b=30)
        state = s.init(PROTO)
        for t in range(8):
            state = s.step(jax.random.fold_in(jax.random.key(1), t), state,
                           batches[t], bcounts[t])
        view = s.extract(jax.random.key(2), state)
        assert int(view.size) <= s.hyper["n"], scheme


# ---------------------------------------------------------------------------
# distributed schemes under shard_map
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", sorted(DISTRIBUTED))
def test_distributed_roundtrip_under_shard_map(scheme):
    if jax.device_count() < 1:
        pytest.skip("no devices")
    from jax.sharding import PartitionSpec as P

    s = make_sampler(scheme, **DISTRIBUTED[scheme])
    nsh = jax.device_count()
    mesh = jax.make_mesh((nsh,), (dist.AXIS,))
    bcap_s = 8

    def run(key, bitems, bcounts):
        state = s.init(PROTO)
        for t in range(3):
            state = s.step(jax.random.fold_in(key, t), state,
                           bitems[t], bcounts[t, 0])
        view = s.extract(jax.random.fold_in(key, 9), state)
        return view.mask, view.size[None]

    f = jax.jit(dist.shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(None, dist.AXIS), P(None, dist.AXIS)),
        out_specs=(P(dist.AXIS), P(dist.AXIS)),
    ))
    bitems = jnp.arange(3 * nsh * bcap_s, dtype=jnp.int32).reshape(
        3, nsh * bcap_s) + 1
    bcounts = jnp.full((3, nsh), 4, jnp.int32)
    mask, sizes = f(jax.random.key(0), bitems, bcounts)
    assert mask.shape[0] % nsh == 0
    assert int(sizes.sum()) >= 0
    if scheme == "drtbs":
        # global bound: full items across shards never exceed n
        assert int(mask.sum()) <= s.hyper["n"]


# ---------------------------------------------------------------------------
# manage loop: fused == stepping the sampler directly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ["rtbs", "sw"])
def test_manage_loop_matches_direct_stepping(scheme):
    n = 50
    sampler = make_sampler("rtbs", n=n, lam=0.1) if scheme == "rtbs" \
        else make_sampler("sw", n=n)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(LinRegStream(seed=0), 12,
                                          batch_size=20)
    key = jax.random.key(42)
    run = make_run_loop(sampler, model, retrain_every=1)
    state_fused, params_fused, trace = run(key, batches, bcounts)

    # drive the raw sampler with the loop's documented key discipline
    state = sampler.init(item_proto(batches))
    for t in range(12):
        k_step, _, _ = tick_keys(key, t)
        bt = jax.tree_util.tree_map(lambda a: a[t], batches)
        state = sampler.step(k_step, state, bt, bcounts[t])

    for a, b in zip(jax.tree_util.tree_leaves(state_fused),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the traced metric/size are well-formed
    assert np.isfinite(np.asarray(trace["metric"])[1:]).all()
    assert (np.asarray(trace["size"]) <= n).all()


def test_manage_step_composes_with_fused_loop():
    """Tick-by-tick driving via make_manage_step reproduces the fused trace."""
    sampler = make_sampler("brs", n=40)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(LinRegStream(seed=1), 10,
                                          batch_size=16)
    key = jax.random.key(7)
    _, _, trace = make_run_loop(sampler, model, retrain_every=2)(
        key, batches, bcounts)

    tick = make_manage_step(sampler, model, retrain_every=2)  # jitted
    state, params = sampler.init(item_proto(batches)), model.init()
    metrics = []
    for t in range(10):
        bt = jax.tree_util.tree_map(lambda a: a[t], batches)
        state, params, m = tick(key, t, state, params, bt, bcounts[t])
        metrics.append(float(m["metric"]))
    np.testing.assert_allclose(np.asarray(trace["metric"]), metrics,
                               rtol=1e-6)


def test_manage_step_donates_and_keeps_reservoir_on_device():
    """ROADMAP PR-3 follow-up (c): the LOCAL per-tick driver donates the
    reservoir state (off-CPU, matching the sharded driver) and never forces
    a per-tick host copy of it -- the whole per-tick drive runs under a
    device-to-host transfer guard; metrics are pulled only afterwards."""
    sampler = make_sampler("rtbs", n=32, lam=0.1)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(LinRegStream(seed=2), 8,
                                          batch_size=16)
    tick = make_manage_step(sampler, model, retrain_every=2)
    assert tick is make_manage_step(sampler, model, retrain_every=2)
    key = jax.random.key(0)
    state, params = sampler.init(item_proto(batches)), model.init()
    ts = [jnp.int32(t) for t in range(8)]
    bts = [jax.tree_util.tree_map(lambda a: a[t], batches) for t in range(8)]
    metrics = []
    with jax.transfer_guard_device_to_host("disallow"):
        for t in range(8):
            prev = state
            state, params, m = tick(key, ts[t], state, params, bts[t],
                                    bcounts[t])
            metrics.append(m["metric"])
            if jax.default_backend() != "cpu":
                # donation: the consumed snapshot's buffers are reused
                assert all(a.is_deleted()
                           for a in jax.tree_util.tree_leaves(prev))
    assert np.isfinite(np.asarray(jnp.stack(metrics))[1:]).all()


def test_manage_loop_learns_linreg():
    """On a stationary stream the managed model reaches the noise floor."""
    sampler = make_sampler("rtbs", n=200, lam=0.1)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(LinRegStream(seed=3), 25,
                                          batch_size=80)
    _, _, trace = make_run_loop(sampler, model)(jax.random.key(0),
                                                batches, bcounts)
    tail = np.asarray(trace["metric"])[-5:]
    assert tail.mean() < 1.5, tail  # noise floor is 1.0


def test_manage_farm_shapes_and_variation():
    sampler = make_sampler("rtbs", n=30, lam=0.2)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(LinRegStream(seed=4), 8,
                                          batch_size=20)
    trace = make_run_farm(sampler, model)(jax.random.key(5), 6,
                                          batches, bcounts)
    assert trace["metric"].shape == (6, 8)
    # independent trials -> sampler randomness actually varies
    assert len(np.unique(np.asarray(trace["metric"])[:, -1])) > 1


def test_naive_bayes_adapter_on_manage_loop():
    s = UsenetLikeStream(seed=0)
    batches, bcounts = materialize_stream(s, 6, batch_size=50)
    model = make_model("naive_bayes", vocab=s.vocab)
    _, _, trace = make_run_loop(make_sampler("sw", n=250), model)(
        jax.random.key(0), batches, bcounts)
    m = np.asarray(trace["metric"])
    assert ((m >= 0) & (m <= 1)).all()
    assert m[1:3].mean() < 0.5  # within the first context, NB fits well


def test_knn_adapter_round_trip():
    model = make_model("knn", cap=51, dim=2, k=3, num_classes=5)
    params = model.init()
    view = SampleView(
        items={"x": jnp.ones((51, 2)), "y": jnp.zeros((51,), jnp.int32)},
        mask=jnp.arange(51) < 20,
        size=jnp.int32(20),
    )
    params = model.fit(jax.random.key(0), params, view)
    batch = {"x": jnp.ones((4, 2)), "y": jnp.zeros((4,), jnp.int32)}
    miss = model.evaluate(params, batch, jnp.int32(4))
    assert float(miss) == 0.0


def test_sgd_adapter_is_scan_safe():
    """The gradient adapter jits and trains on a toy quadratic model."""
    def loss(params, batch):
        pred = batch["tokens"][:, 0] * params["w"]
        return jnp.mean((pred - batch["tokens"][:, 1]) ** 2)

    def train_step(params, opt, batch):
        g = jax.grad(loss)(params, batch)
        params = jax.tree_util.tree_map(lambda p, d: p - 0.1 * d, params, g)
        return params, opt, {"loss": loss(params, batch)}

    adapter = make_sgd_adapter(
        init_params=lambda: {"w": jnp.float32(0.0)},
        train_step=train_step,
        init_opt_state=lambda p: jnp.int32(0),
        loss=loss,
        batch_field="tokens",
        train_batch=8,
        retrain_steps=20,
    )
    state = adapter.init()
    # sample: y = 3x pairs
    xs = jnp.linspace(1.0, 2.0, 32)
    view = SampleView(
        items=jnp.stack([xs, 3.0 * xs], axis=1),
        mask=jnp.ones((32,), bool),
        size=jnp.int32(32),
    )
    state = jax.jit(adapter.fit)(jax.random.key(0), state, view)
    assert abs(float(state["params"]["w"]) - 3.0) < 0.2
    # empty-sample guard: fit is a no-op
    empty = SampleView(items=view.items, mask=jnp.zeros((32,), bool),
                       size=jnp.int32(0))
    state2 = jax.jit(adapter.fit)(jax.random.key(1), state, empty)
    assert float(state2["params"]["w"]) == float(state["params"]["w"])


def test_run_loop_memoized_no_retrace():
    """run_loop/run_farm one-shot wrappers must not rebuild + re-jit the scan
    per call: make_run_loop is memoized on (sampler, model, retrain_every)
    and the jit cache shows exactly one trace for repeat same-shape runs."""
    from repro.manage import run_loop

    sampler = make_sampler("rtbs", n=20, lam=0.1)
    model = make_model("linreg", dim=2)
    r1 = make_run_loop(sampler, model)
    assert r1 is make_run_loop(sampler, model)
    assert r1 is not make_run_loop(sampler, model, retrain_every=2)
    assert make_run_farm(sampler, model) is make_run_farm(sampler, model)
    # an equivalent-but-fresh sampler is a different program (identity hash)
    assert make_run_loop(make_sampler("rtbs", n=20, lam=0.1), model) is not r1

    batches, bcounts = materialize_stream(LinRegStream(seed=2), 5,
                                          batch_size=8)
    run_loop(jax.random.key(0), sampler, model, batches, bcounts)
    run_loop(jax.random.key(1), sampler, model, batches, bcounts)
    assert r1._cache_size() == 1  # second call hit the jit cache, no retrace


def test_sgd_adapter_row_loss_masks_padding():
    """With row_loss, evaluate is a bcount-masked prefix mean: zero-padded
    eval rows (e.g. sharded per-shard segments) cannot skew the metric."""
    def row_loss(params, batch):
        return (batch["tokens"][:, 0] * params["w"] - batch["tokens"][:, 1]) ** 2

    adapter = make_sgd_adapter(
        init_params=lambda: {"w": jnp.float32(3.0)},
        train_step=lambda p, o, b: (p, o, {}),
        init_opt_state=lambda p: jnp.int32(0),
        loss=lambda p, b: jnp.mean(row_loss(p, b)),
        row_loss=row_loss,
        batch_field="tokens",
        train_batch=4,
        retrain_steps=1,
    )
    state = adapter.init()
    valid = jnp.asarray([[1.0, 3.0], [2.0, 6.0]])          # exact fit: loss 0
    garbage = jnp.zeros((2, 2)).at[:, 1].set(99.0)          # would blow up
    batch = jnp.concatenate([valid, garbage])
    assert float(adapter.evaluate(state, batch, jnp.int32(2))) == 0.0
    assert float(adapter.evaluate(state, batch, jnp.int32(4))) > 1.0


def test_manage_loop_rejects_distributed_samplers():
    """Per-shard schemes must fail fast, not die inside jax with an
    unbound-axis error."""
    model = make_model("linreg", dim=2)
    for scheme in sorted(DISTRIBUTED):
        s = make_sampler(scheme, **DISTRIBUTED[scheme])
        with pytest.raises(ValueError, match="per-shard"):
            make_run_loop(s, model)
        with pytest.raises(ValueError, match="per-shard"):
            make_manage_step(s, model)


def test_empty_tick_metric_is_nan():
    """bcount == 0 must not report a perfect score."""
    model = make_model("linreg", dim=2)
    batch = {"x": jnp.ones((4, 2)), "y": jnp.ones((4,))}
    m = model.evaluate(model.init(), batch, jnp.int32(0))
    assert np.isnan(float(m))
    assert np.isfinite(float(model.evaluate(model.init(), batch, jnp.int32(3))))


def test_model_registry():
    from repro.manage import available_models

    assert {"linreg", "naive_bayes", "knn"} <= set(available_models())
    with pytest.raises(ValueError, match="unknown model"):
        make_model("nope")
