"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
pure-jnp oracles (interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.reservoir_compact import ops as rc_ops, ref as rc_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,H,KV,hd,causal,window,dtype",
    [
        (2, 128, 4, 2, 32, True, 0, jnp.float32),
        (1, 256, 4, 1, 16, True, 0, jnp.float32),     # MQA
        (2, 128, 4, 4, 64, False, 0, jnp.float32),    # MHA, bidirectional
        (1, 256, 2, 2, 32, True, 64, jnp.float32),    # sliding window
        (1, 128, 8, 2, 32, True, 0, jnp.bfloat16),    # bf16
        (2, 384, 6, 2, 32, True, 96, jnp.bfloat16),   # swa + gqa + bf16
    ],
)
def test_flash_attention_matches_ref(B, S, H, KV, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    got = fa_ops.flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64
    )
    want = fa_ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


def test_flash_attention_block_shape_invariance():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    outs = [
        np.asarray(fa_ops.flash_attention(q, k, v, block_q=bq, block_k=bk))
        for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,H,G,N,P,chunk,dtype",
    [
        (2, 64, 4, 1, 16, 16, 16, jnp.float32),
        (1, 128, 4, 2, 32, 16, 32, jnp.float32),   # 2 groups
        (2, 64, 2, 2, 16, 32, 64, jnp.float32),    # chunk == S
        (1, 64, 4, 1, 16, 16, 16, jnp.bfloat16),
    ],
)
def test_ssd_scan_matches_recurrence(B, S, H, G, N, P, chunk, dtype):
    ks = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.5
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N), dtype) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N), dtype) * 0.5
    y, st = ssd_ops.ssd_scan(x, dt, a, Bm, Cm, chunk=chunk)
    # oracle: exact per-token recurrence with per-head broadcast B/C
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    Ch = jnp.repeat(Cm, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    af = jnp.tile(a, B)
    y_ref, st_ref = ssd_ref.ssd_ref(xf, dtf, af, Bh, Ch)
    y_ref = y_ref.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    st_ref = st_ref.reshape(B, H, N, P)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        atol=tol, rtol=tol,
    )
    np.testing.assert_allclose(
        np.asarray(st), np.asarray(st_ref), atol=tol, rtol=tol
    )


def test_ssd_model_path_matches_kernel():
    """The model's jnp chunked path and the Pallas kernel agree."""
    from repro.config import ModelConfig
    from repro.models import ssm as S

    cfg = ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=32, ssm_state=16,
        ssm_head_dim=16, ssm_groups=1, ssm_chunk=16,
    )
    B, Sq = 2, 64
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (B, Sq, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, Sq, H))) * 0.5
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, Sq, 1, 16)) * 0.5
    Cm = jax.random.normal(ks[4], (B, Sq, 1, 16)) * 0.5
    y1, st1 = S.ssd_chunked(cfg, x, dt, a, Bm, Cm)
    y2, st2 = ssd_ops.ssd_scan(x, dt, a, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# reservoir compaction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "cap,D,frac,block,dtype",
    [
        (256, 8, 0.5, 64, jnp.float32),
        (128, 16, 0.0, 128, jnp.float32),   # keep nothing
        (128, 16, 1.0, 32, jnp.float32),    # keep everything
        (512, 4, 0.25, 128, jnp.int32),     # int payload (token ids)
        (256, 8, 0.9, 64, jnp.bfloat16),
        (301, 8, 0.5, 128, jnp.float32),    # cap not a block multiple (padded)
    ],
)
def test_reservoir_compact_matches_ref(cap, D, frac, block, dtype):
    """impl="interpret" executes the kernel BODY on CPU (the auto route is
    the jnp oracle off-TPU, which would test ref against itself)."""
    k1, k2 = jax.random.split(jax.random.key(4))
    if dtype == jnp.int32:
        items = jax.random.randint(k1, (cap, D), 0, 1000, jnp.int32)
    else:
        items = jax.random.normal(k1, (cap, D), dtype)
    mask = jax.random.bernoulli(k2, frac, (cap,))
    got, cnt = rc_ops.reservoir_compact(items, mask, block=block,
                                        impl="interpret")
    want, cnt_ref = rc_ref.compact_ref(items, mask)
    assert int(cnt) == int(cnt_ref) == int(np.asarray(mask).sum())
    np.testing.assert_array_equal(
        np.asarray(got[: int(cnt)]), np.asarray(want[: int(cnt)])
    )


@settings(max_examples=20, deadline=None)
@given(
    cap_blocks=st.integers(1, 4),
    d=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_reservoir_compact_property(cap_blocks, d, seed):
    """Property: stable compaction == numpy boolean indexing, any mask."""
    cap = 64 * cap_blocks
    rs = np.random.RandomState(seed)
    items = jnp.asarray(rs.randint(0, 10**6, (cap, d)), jnp.int32)
    mask = jnp.asarray(rs.rand(cap) < rs.rand())
    got, cnt = rc_ops.reservoir_compact(items, mask, block=64,
                                        impl="interpret")
    want = np.asarray(items)[np.asarray(mask)]
    assert int(cnt) == want.shape[0]
    np.testing.assert_array_equal(np.asarray(got[: int(cnt)]), want)
