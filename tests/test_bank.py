"""repro.bank -- keyed multi-tenant sampler banks (DESIGN.md Sec. 13):

  * routing: segment bookkeeping vs a numpy reference, static per-key bcap
    overflow accounting, invalid-row exclusion;
  * the banked payload kernel's grid dimension on the interpret route is
    bit-identical to the vmap-of-ref parity oracle;
  * a bank tick is BIT-identical to vmapping the standalone fused step over
    the routed sub-batches (rtbs and ttbs), untouched keys taking exactly
    the pure-decay pending multiply;
  * per-key marginal equivalence (the acceptance criterion): key k's
    reservoir in a K-key bank under a Zipf keyed stream is distributionally
    identical to a standalone R-TBS fed only key-k arrivals -- lazily
    (wall-clock dt gaps) or eagerly (empty ticks) -- and all three match
    the Theorem 4.1/4.2 inclusion probabilities;
  * extract/size consistency (mask.sum() == size, size path == extract
    sizes) including pending-decay settling, K >= 4096 in one jitted scan,
    and the bank-level manage loops (shared pool, per-key farm, per-key
    controller, key-sharded mesh).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import decay as dk
from repro.bank import make_bank, route, subbatches
from repro.core import latent as lt
from repro.core import rtbs, simple
from repro.data.streams import KeyedStream, LinRegStream
from repro.kernels.tbs_step import ops as tbs_ops
from repro.kernels.tbs_step import ref as tbs_ref
from repro.manage import (
    make_bank_run_loop,
    make_model,
    make_sharded_bank_loop,
    materialize_stream,
    shard_keyed_stream,
)

PROTO = jax.ShapeDtypeStruct((2,), jnp.float32)


def _zipf_keys(rs, K, shape, alpha=1.2):
    w = (1.0 + np.arange(K)) ** -alpha
    return rs.choice(K, size=shape, p=w / w.sum()).astype(np.int32)


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------
def test_route_matches_numpy_reference():
    K, b, bcap = 11, 32, 4
    rs = np.random.RandomState(0)
    keys = rs.randint(0, K, size=b).astype(np.int32)
    bcount = 23
    r = route(jnp.asarray(keys), jnp.int32(bcount), num_keys=K, bcap=bcap)

    valid = keys[:bcount]
    uniq = np.unique(valid)
    nt = int(r.ntouched)
    assert nt == len(uniq)
    np.testing.assert_array_equal(np.asarray(r.touched)[:nt], uniq)
    assert (np.asarray(r.touched)[nt:] == K).all()
    order = np.asarray(r.order)
    sorted_keys = np.where(np.arange(b) < bcount, keys, K)[order]
    # stable key sort: ascending keys, arrival order within a key
    assert (np.diff(sorted_keys) >= 0).all()
    total_drop = 0
    for i, k in enumerate(uniq):
        raw = int((valid == k).sum())
        want = min(raw, bcap)
        assert int(r.counts[i]) == want
        assert int(r.dropped[i]) == raw - want
        total_drop += raw - want
        s = int(r.starts[i])
        seg = order[s:s + want]
        np.testing.assert_array_equal(
            seg, np.nonzero(valid == k)[0][:want]
        )  # first-bcap in arrival order
    assert int(r.overflow) == total_drop
    # rows past ntouched carry zero counts
    assert (np.asarray(r.counts)[nt:] == 0).all()


def test_route_discards_out_of_range_keys():
    """Out-of-range ids are dropped and counted, NEVER clipped onto a real
    tenant (clipping would silently corrupt key num_keys-1's reservoir)."""
    K, bcap = 4, 4
    keys = jnp.asarray([0, 7, 3, -1, 3, K, 2, 1], jnp.int32)
    r = route(keys, jnp.int32(6), num_keys=K, bcap=bcap)  # row 6,7 invalid
    assert int(r.invalid) == 3            # 7, -1, K within the valid prefix
    nt = int(r.ntouched)
    np.testing.assert_array_equal(np.asarray(r.touched)[:nt], [0, 3])
    np.testing.assert_array_equal(np.asarray(r.counts)[:nt], [1, 2])
    assert int(r.overflow) == 0


def test_subbatches_windows_are_prefix_valid():
    K, b, bcap = 5, 16, 3
    rs = np.random.RandomState(1)
    keys = rs.randint(0, K, size=b).astype(np.int32)
    payload = rs.randn(b, 2).astype(np.float32)
    r = route(jnp.asarray(keys), jnp.int32(b), num_keys=K, bcap=bcap)
    sub = subbatches(r, jnp.asarray(payload), bcap=bcap)
    for i in range(int(r.ntouched)):
        k = int(r.touched[i])
        c = int(r.counts[i])
        rows = np.nonzero(keys == k)[0][:c]
        np.testing.assert_array_equal(np.asarray(sub)[i, :c], payload[rows])


# ---------------------------------------------------------------------------
# the banked kernel grid dimension: interpret route == vmap-of-ref oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("T,cap,bcap,D", [(5, 16, 8, 4), (3, 33, 5, 2)])
def test_banked_apply_interpret_matches_vmap_of_ref(T, cap, bcap, D):
    rs = np.random.RandomState(2)
    items = jnp.asarray(rs.randn(T, cap, D), jnp.float32)
    batch = jnp.asarray(rs.randn(T, bcap, D), jnp.float32)
    src = jnp.asarray(rs.randint(0, cap + bcap, size=(T, cap)), jnp.int32)
    want = tbs_ref.apply_banked_ref(items, batch, src)
    got = tbs_ops.tbs_step_apply_banked(items, batch, src, impl="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_ref = tbs_ops.tbs_step_apply_banked(items, batch, src, impl="ref")
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))
    # int payloads widen and cast back
    ii = jnp.asarray(rs.randint(-5, 5, size=(T, cap, D)), jnp.int8)
    bb = jnp.asarray(rs.randint(-5, 5, size=(T, bcap, D)), jnp.int8)
    gi = tbs_ops.tbs_step_apply_banked(ii, bb, src, impl="interpret")
    wi = tbs_ref.apply_banked_ref(
        ii.astype(jnp.int32), bb.astype(jnp.int32), src
    )
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    assert gi.dtype == jnp.int8


# ---------------------------------------------------------------------------
# bank tick == vmap-of-single over the routed sub-batches, bit for bit
# ---------------------------------------------------------------------------
def test_bank_rtbs_step_bit_parity_with_vmap_of_single():
    K, n, bcap, b, T = 8, 5, 4, 16, 5
    lam = 0.3
    d = jnp.float32(math.exp(-lam))
    bank = make_bank("rtbs", num_keys=K, n=n, lam=lam, bcap=bcap)
    bstep = jax.jit(bank.step)
    st = bank.init(PROTO)
    rs = np.random.RandomState(3)
    key0 = jax.random.key(7)
    for t in range(T):
        keys = jnp.asarray(rs.randint(0, K, size=b), jnp.int32)
        payload = jnp.asarray(rs.randn(b, 2), jnp.float32)
        kt = jax.random.fold_in(key0, t)

        pend = np.array(st.pending * d, np.float32)
        r = route(keys, jnp.int32(b), num_keys=K, bcap=bcap)
        sub = subbatches(r, payload, bcap=bcap)
        exp_items = np.asarray(jax.tree_util.tree_leaves(st.items)[0]).copy()
        nfull = np.asarray(st.nfull).copy()
        C = np.asarray(st.weight).copy()
        W = np.asarray(st.total_weight).copy()
        for i in range(int(r.ntouched)):
            k_id = int(r.touched[i])
            st_k = rtbs.RTBSState(
                lat=lt.Latent(items=jnp.asarray(exp_items[k_id]),
                              nfull=jnp.int32(nfull[k_id]),
                              weight=jnp.float32(C[k_id])),
                total_weight=jnp.float32(W[k_id]),
            )
            out = rtbs.step(
                jax.random.fold_in(kt, k_id), st_k,
                jax.tree_util.tree_map(lambda a: a[i], sub), r.counts[i],
                n=n, decay=jnp.float32(pend[k_id]),
            )
            exp_items[k_id] = np.asarray(out.lat.items)
            nfull[k_id] = int(out.lat.nfull)
            C[k_id] = np.float32(out.lat.weight)
            W[k_id] = np.float32(out.total_weight)
            pend[k_id] = 1.0

        st = bstep(kt, st, keys, payload, jnp.int32(b))
        np.testing.assert_array_equal(np.asarray(st.items), exp_items)
        np.testing.assert_array_equal(np.asarray(st.nfull), nfull)
        np.testing.assert_array_equal(np.asarray(st.weight), C)
        np.testing.assert_array_equal(np.asarray(st.total_weight), W)
        np.testing.assert_array_equal(np.asarray(st.pending), pend)
    # something actually decayed lazily at some point
    assert (np.asarray(st.pending) <= 1.0).all()


def test_bank_ttbs_step_bit_parity_with_vmap_of_single():
    K, n, cap, bcap, b, T = 6, 4, 8, 4, 12, 5
    lam = 0.3
    batch_size = 2.0
    d = jnp.float32(math.exp(-lam))
    q = jnp.float32(np.clip(n * (1.0 - np.float32(math.exp(-lam)))
                            / np.float32(batch_size), 0.0, 1.0))
    bank = make_bank("ttbs", num_keys=K, n=n, lam=lam,
                     batch_size=batch_size, cap=cap, bcap=bcap)
    bstep = jax.jit(bank.step)
    st = bank.init(PROTO)
    rs = np.random.RandomState(4)
    key0 = jax.random.key(11)
    for t in range(T):
        keys = jnp.asarray(rs.randint(0, K, size=b), jnp.int32)
        payload = jnp.asarray(rs.randn(b, 2), jnp.float32)
        kt = jax.random.fold_in(key0, t)

        pend = np.array(st.pending * d, np.float32)
        r = route(keys, jnp.int32(b), num_keys=K, bcap=bcap)
        sub = subbatches(r, payload, bcap=bcap)
        exp_items = np.asarray(jax.tree_util.tree_leaves(st.items)[0]).copy()
        cnt = np.asarray(st.nfull).copy()
        W = np.asarray(st.total_weight).copy()
        for i in range(int(r.ntouched)):
            k_id = int(r.touched[i])
            bs = simple.BufferState(
                items=jnp.asarray(exp_items[k_id]),
                count=jnp.int32(cnt[k_id]),
                total_weight=jnp.float32(W[k_id]),
                overflow=jnp.int32(0),
            )
            out = simple.ttbs_step(
                jax.random.fold_in(kt, k_id), bs,
                jax.tree_util.tree_map(lambda a: a[i], sub), r.counts[i],
                p=jnp.float32(pend[k_id]), q=q,
            )
            exp_items[k_id] = np.asarray(out.items)
            cnt[k_id] = int(out.count)
            W[k_id] = np.float32(out.total_weight)
            pend[k_id] = 1.0

        st = bstep(kt, st, keys, payload, jnp.int32(b))
        np.testing.assert_array_equal(np.asarray(st.items), exp_items)
        np.testing.assert_array_equal(np.asarray(st.nfull), cnt)
        # W is bookkeeping-only for T-TBS (never read by the algorithm);
        # XLA's fma contraction of p*W + b differs between the bank's
        # vectorized compile and the scalar step, so allow 1 ulp there
        np.testing.assert_allclose(np.asarray(st.total_weight), W,
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(st.pending), pend)


# ---------------------------------------------------------------------------
# per-key marginal equivalence: the acceptance criterion
# ---------------------------------------------------------------------------
def test_bank_per_key_theorem_4_1_equivalence():
    """Key k's reservoir in a K-key bank under a non-trivial Zipf keyed
    stream is distributionally identical to a standalone R-TBS fed only
    key-k arrivals -- Theorem 4.1/4.2 re-run per key.

    Three executions over the SAME fixed keyed stream, Monte-Carlo'd over
    sampler randomness: (a) the fused bank (lazy pending decay, composed
    tick maps), (b) a standalone sampler fed only the key's arrival ticks
    with wall-clock gaps (``decay = e^{-lam dt}``), (c) a standalone
    sampler stepped EVERY tick (empty batches on non-arrival ticks) -- the
    eager chain the lazy composition must match. All three must reproduce
    the analytic inclusion probabilities Pr[i in S] = (C_T/W_T) e^{-lam a}
    for item age a, for a saturated (popular) and an unsaturated (rare,
    irregular) key."""
    K, n, T, b, lam, trials = 8, 6, 8, 16, 0.25, 10000
    bcap = b  # no routing drops: every arrival reaches its reservoir
    d = math.exp(-lam)
    rs = np.random.RandomState(5)
    keys = _zipf_keys(rs, K, (T, b))
    # payload encodes the arrival tick: (t+1)*100 + row
    payload = (np.arange(1, T + 1)[:, None] * 100
               + np.arange(b)[None, :]).astype(np.float32)
    payload = np.repeat(payload[:, :, None], 2, axis=2)
    keys_j, payload_j = jnp.asarray(keys), jnp.asarray(payload)

    bank = make_bank("rtbs", num_keys=K, n=n, lam=lam, bcap=bcap)

    def run_bank(trial_key, focal):
        st = bank.init(PROTO)

        def body(c, t):
            return bank.step(jax.random.fold_in(trial_key, t), c,
                             keys_j[t], payload_j[t], jnp.int32(b)), None

        st, _ = jax.lax.scan(body, st, jnp.arange(T))
        view = bank.extract(jax.random.fold_in(trial_key, 777), st,
                            jnp.asarray([focal]))
        ticks = (view.items[0, :, 0] // 100).astype(jnp.int32)
        counts = jnp.zeros((T + 1,), jnp.float32).at[ticks].add(
            view.mask[0].astype(jnp.float32), mode="drop")
        return counts[1:]

    def run_standalone(trial_key, focal, lazy):
        """Feed only key-``focal``'s arrivals; ``lazy`` composes gaps into
        one decay factor (dt form), else steps every tick (eager chain)."""
        st = rtbs.init(PROTO, n)
        arrived = keys == focal        # [T, b] (numpy, fixed stream)
        prev = -1
        for t in range(T):
            c_t = int(arrived[t].sum())
            if not lazy or c_t > 0:
                gap = t - prev
                rows = np.nonzero(arrived[t])[0]
                bt = np.zeros((bcap, 2), np.float32)
                bt[:c_t] = payload[t, rows]
                st = rtbs.step(
                    jax.random.fold_in(jax.random.fold_in(trial_key, t),
                                       focal),
                    st, jnp.asarray(bt), jnp.int32(c_t), n=n,
                    decay=jnp.float32(d ** (gap if lazy else 1)),
                )
                prev = t
        # trailing gap settles exactly as the bank extract does
        kk = jax.random.fold_in(jax.random.fold_in(trial_key, 777), focal)
        k_ds, k_re = jax.random.split(kk)
        w_eff = jnp.float32(d ** (T - 1 - prev)) * st.total_weight
        lat = lt.downsample(k_ds, st.lat,
                            jnp.minimum(st.lat.weight, w_eff),
                            max_deleted=bcap)
        mask, _ = lt.realize(k_re, lat)
        ticks = (lat.items[:, 0] // 100).astype(jnp.int32)
        counts = jnp.zeros((T + 1,), jnp.float32).at[ticks].add(
            mask.astype(jnp.float32), mode="drop")
        return counts[1:]

    tkeys = jax.random.split(jax.random.key(0), trials)
    for focal in (0, 5):               # popular/saturated and rare/irregular
        c = (keys == focal).sum(axis=1).astype(np.float64)  # arrivals/tick
        assert c.sum() > 0
        if focal == 5:
            assert (c == 0).any()      # genuinely irregular: skipped ticks
        W = 0.0
        for t in range(T):
            W = d * W + c[t]
        C = min(n, W)
        expect = np.array([
            (C / W) * d ** (T - 1 - t) if c[t] else 0.0 for t in range(T)
        ])

        got = {}
        got["bank"] = np.asarray(
            jax.jit(jax.vmap(lambda k: run_bank(k, focal)))(tkeys)
        ).mean(axis=0)
        lazy_fn = jax.jit(jax.vmap(lambda k: run_standalone(k, focal, True)))
        eager_fn = jax.jit(jax.vmap(lambda k: run_standalone(k, focal,
                                                             False)))
        got["lazy"] = np.asarray(lazy_fn(tkeys)).mean(axis=0)
        got["eager"] = np.asarray(eager_fn(tkeys)).mean(axis=0)
        denom = np.where(c > 0, c, 1.0)
        for name, counts in got.items():
            probs = counts / denom
            for t in range(T):
                assert abs(probs[t] - expect[t]) < 0.03, (
                    focal, name, t, probs[t], expect[t]
                )
        # the bank and the dt-fed standalone agree with each other too
        np.testing.assert_allclose(got["bank"] / denom,
                                   got["lazy"] / denom, atol=0.03)


# ---------------------------------------------------------------------------
# extract / size / overflow / validation
# ---------------------------------------------------------------------------
def test_bank_extract_size_consistent_and_settles_pending():
    K, n, bcap, b = 12, 6, 8, 24
    bank = make_bank("rtbs", num_keys=K, n=n, lam=0.4, bcap=bcap)
    bstep = jax.jit(bank.step)
    st = bank.init(PROTO)
    rs = np.random.RandomState(6)
    key0 = jax.random.key(2)
    for t in range(6):
        keys = jnp.asarray(_zipf_keys(rs, K, b), jnp.int32)
        st = bstep(jax.random.fold_in(key0, t), st, keys,
                   jnp.asarray(rs.randn(b, 2), jnp.float32),
                   jnp.int32(b))
    # several more empty ticks: pure pending decay, NO payload movement
    items_before = np.asarray(st.items).copy()
    for t in range(6, 10):
        st = bstep(jax.random.fold_in(key0, t), st,
                   jnp.zeros((b,), jnp.int32),
                   jnp.zeros((b, 2), jnp.float32), jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(st.items), items_before)
    assert (np.asarray(st.pending) < 1.0).all()

    all_keys = jnp.arange(K)
    view = jax.jit(bank.extract)(jax.random.key(9), st, all_keys)
    sizes = jax.jit(bank.size)(jax.random.key(9), st, all_keys)
    np.testing.assert_array_equal(np.asarray(view.mask.sum(axis=1)),
                                  np.asarray(view.size))
    np.testing.assert_array_equal(np.asarray(sizes), np.asarray(view.size))
    # the deferred decay is visible: effective sizes are bounded by the
    # decayed weight, not the stored one
    w_eff = np.asarray(st.pending * st.total_weight)
    assert (np.asarray(sizes) <= np.ceil(np.minimum(n, w_eff) + 1e-6)).all()
    assert (np.asarray(sizes) <= n).all()

    # ttbs: same consistency contract
    bank2 = make_bank("ttbs", num_keys=K, n=4, lam=0.4, batch_size=2.0,
                      bcap=bcap)
    bstep2 = jax.jit(bank2.step)
    st2 = bank2.init(PROTO)
    for t in range(6):
        keys = jnp.asarray(_zipf_keys(rs, K, b), jnp.int32)
        st2 = bstep2(jax.random.fold_in(key0, t), st2, keys,
                     jnp.asarray(rs.randn(b, 2), jnp.float32),
                     jnp.int32(b))
    v2 = jax.jit(bank2.extract)(jax.random.key(3), st2, all_keys)
    s2 = jax.jit(bank2.size)(jax.random.key(3), st2, all_keys)
    np.testing.assert_array_equal(np.asarray(v2.mask.sum(axis=1)),
                                  np.asarray(v2.size))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(v2.size))


def test_bank_routing_overflow_accounting_through_step():
    K, n, bcap, b = 4, 8, 2, 16
    bank = make_bank("rtbs", num_keys=K, n=n, lam=0.1, bcap=bcap)
    st = bank.init(PROTO)
    # every item hits key 0: 16 arrivals, 2 accepted per tick
    keys = jnp.zeros((b,), jnp.int32)
    payload = jnp.ones((b, 2), jnp.float32)
    bstep = jax.jit(bank.step)
    for t in range(3):
        st = bstep(jax.random.fold_in(jax.random.key(0), t), st, keys,
                   payload, jnp.int32(b))
    assert int(st.overflow[0]) == 3 * (b - bcap)
    assert (np.asarray(st.overflow)[1:] == 0).all()
    # the accepted-only weight accounting: W counts the bcap accepted items
    d = math.exp(-0.1)
    W = 0.0
    for _ in range(3):
        W = d * W + bcap
    np.testing.assert_allclose(float(st.total_weight[0]), W, rtol=1e-5)


def test_bank_step_dt_consumes_wallclock_gaps():
    """ROADMAP decay follow-up (b) at the bank level: one step spanning
    dt=3 equals three unit steps, up to f32 rounding of d^3 (exponential
    schedules are exact in the gap: e^{-lam dt})."""
    K, n, bcap, b = 6, 5, 4, 8
    bank = make_bank("rtbs", num_keys=K, n=n, lam=0.2, bcap=bcap)
    rs = np.random.RandomState(7)
    keys = jnp.asarray(rs.randint(0, K, size=b), jnp.int32)
    payload = jnp.asarray(rs.randn(b, 2), jnp.float32)
    key0 = jax.random.key(1)
    bstep = jax.jit(bank.step)
    st = bank.init(PROTO)
    st = bstep(jax.random.fold_in(key0, 0), st, keys, payload,
               jnp.int32(b))

    empty_k = jnp.zeros((b,), jnp.int32)
    empty_p = jnp.zeros((b, 2), jnp.float32)
    st_unit = st
    for t in range(1, 4):
        st_unit = bstep(jax.random.fold_in(key0, t), st_unit, empty_k,
                        empty_p, jnp.int32(0))
    st_dt = bank.step(jax.random.fold_in(key0, 9), st, empty_k, empty_p,
                      jnp.int32(0), dt=jnp.float32(3.0))
    np.testing.assert_allclose(np.asarray(st_dt.pending),
                               np.asarray(st_unit.pending), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(st_dt.items),
                                  np.asarray(st_unit.items))
    np.testing.assert_array_equal(np.asarray(st_dt.total_weight),
                                  np.asarray(st_unit.total_weight))


def test_make_bank_validation():
    with pytest.raises(ValueError, match="unknown bank scheme"):
        make_bank("nope", num_keys=4, n=2)
    with pytest.raises(ValueError, match="num_keys"):
        make_bank("rtbs", num_keys=0, n=2, lam=0.1)
    with pytest.raises(ValueError, match="exactly one"):
        make_bank("rtbs", num_keys=4, n=2)
    b = make_bank("rtbs", num_keys=4, n=2,
                  decay=dk.polynomial(0.8))
    st = b.init(PROTO)
    assert st.dstate is not None        # time-varying bookkeeping carried
    assert "SamplerBank(rtbs" in repr(b)
    # out-of-range key ids fail eagerly instead of silently aliasing the
    # last key's reservoir (the global-vs-local id foot-gun of sharded banks)
    with pytest.raises(ValueError, match="key_ids"):
        b.extract(jax.random.key(0), st, jnp.asarray([0, 4]))
    with pytest.raises(ValueError, match="key_ids"):
        b.size(jax.random.key(0), st, jnp.asarray([-1]))
    from repro.manage import make_bank_run_loop as mkloop
    from repro.manage import make_model as mkmodel
    with pytest.raises(ValueError, match="train_keys"):
        mkloop(b, mkmodel("linreg", dim=2), train_keys=range(9))


def test_bank_4096_keys_one_jitted_scan():
    """The acceptance shape: K >= 4096 keys advanced by one jitted scan
    (both schemes), with only the touched keys paying payload work."""
    K, n, bcap, b, T = 4096, 8, 8, 64, 4
    rs = np.random.RandomState(8)
    keys = jnp.asarray(_zipf_keys(rs, K, (T, b)), jnp.int32)
    payload = jnp.asarray(rs.randn(T, b, 2), jnp.float32)
    for scheme, hyper in [("rtbs", dict(n=n)),
                          ("ttbs", dict(n=n, batch_size=1.0, cap=n + 1))]:
        bank = make_bank(scheme, num_keys=K, lam=0.1, bcap=bcap, **hyper)

        @jax.jit
        def run(key, bank=bank):
            def body(c, t):
                return bank.step(jax.random.fold_in(key, t), c, keys[t],
                                 payload[t], jnp.int32(b)), None

            st, _ = jax.lax.scan(body, bank.init(PROTO), jnp.arange(T))
            return st

        st = run(jax.random.key(0))
        touched = np.unique(np.asarray(keys))
        w = np.asarray(st.total_weight)
        assert (w[touched] > 0).any()
        untouched = np.setdiff1d(np.arange(K), touched)
        assert (w[untouched] == 0).all()
        assert jax.tree_util.tree_leaves(st.items)[0].shape[0] == K


# ---------------------------------------------------------------------------
# bank-level manage loops
# ---------------------------------------------------------------------------
def _keyed_stream(K=32, T=12, b=24):
    stream = KeyedStream(base=LinRegStream(seed=0), num_keys=K, alpha=1.2,
                         flip_every=6)
    return materialize_stream(stream, T, batch_size=b,
                              fields=("key", "x", "y"))


def test_bank_run_loop_shared_and_superbatch_bit_identity():
    K, Q = 32, 4
    batches, bcounts = _keyed_stream(K=K)
    bank = make_bank("rtbs", num_keys=K, n=10, lam=0.1, bcap=8)
    model = make_model("linreg", dim=2)
    run = make_bank_run_loop(bank, model, retrain_every=3,
                             train_keys=range(Q))
    assert run is make_bank_run_loop(bank, model, retrain_every=3,
                                     train_keys=range(Q))
    out1 = run(jax.random.key(0), batches, bcounts)
    assert out1[2]["metric"].shape == (12,)
    assert out1[2]["size"].shape == (12, Q)
    assert np.isfinite(np.asarray(out1[2]["metric"])[1:]).all()
    run_sb = make_bank_run_loop(bank, model, retrain_every=3,
                                train_keys=range(Q), superbatch=3)
    out3 = run_sb(jax.random.key(0), batches, bcounts)
    _assert_trees_equal(out1, out3)


def test_bank_run_loop_per_key_farm_and_controller():
    K, Q = 32, 4
    batches, bcounts = _keyed_stream(K=K)
    bank = make_bank("rtbs", num_keys=K, n=10, lam=0.1, bcap=8)
    model = make_model("linreg", dim=2)
    run = make_bank_run_loop(bank, model, retrain_every=3,
                             train_keys=range(Q), per_key=True)
    state, params, trace = run(jax.random.key(0), batches, bcounts)
    assert trace["metric"].shape == (12, Q)
    assert np.asarray(params).shape == (Q, 3)
    m = np.asarray(trace["metric"])
    # per-key prequential eval: NaN exactly on ticks the key has no arrivals
    arrive = np.zeros((12, Q), bool)
    kk = np.asarray(batches["key"])
    for q in range(Q):
        arrive[:, q] = (kk == q).any(axis=1)
    np.testing.assert_array_equal(np.isfinite(m), arrive)
    # the popular keys' models actually differ (trained per key)
    assert len({np.asarray(params)[q].tobytes() for q in range(Q)}) > 1

    ctrl = dk.loss_ratio(lam0=0.1, lam_min=0.01, lam_max=1.0)
    runc = make_bank_run_loop(bank, model, retrain_every=3,
                              train_keys=range(Q), per_key=True,
                              controller=ctrl)
    state, params, trace = runc(jax.random.key(0), batches, bcounts)
    assert trace["metric"].shape == (12, Q)
    assert np.isfinite(np.asarray(trace["metric"])).any()


def test_per_key_eval_windows_never_leak_other_tenants():
    """The farm mode's per-key eval windows are zero-padded past each key's
    count: an adapter that ignores bcount must still never see another
    tenant's rows."""
    from repro.manage.bank_loop import _train_windows

    bank = make_bank("rtbs", num_keys=8, n=4, lam=0.1, bcap=4)
    keys = jnp.asarray([0, 1, 0, 2, 1, 5, 0, 0], jnp.int32)
    payload = (jnp.arange(8, dtype=jnp.float32)[:, None]
               * jnp.ones((1, 2)) + 1.0)
    tk = jnp.asarray([0, 1, 3], jnp.int32)
    windows, counts = _train_windows(bank, keys, payload, jnp.int32(6), tk)
    # rows 6-7 sit past bcount: key 0 has valid arrivals at rows 0 and 2
    np.testing.assert_array_equal(np.asarray(counts), [2, 2, 0])
    w = np.asarray(windows)
    np.testing.assert_array_equal(w[0, :2, 0], [1, 3])     # key 0 arrivals
    np.testing.assert_array_equal(w[1, :2, 0], [2, 5])     # key 1 arrivals
    assert (w[0, 2:] == 0).all() and (w[1, 2:] == 0).all()
    assert (w[2] == 0).all()                               # key 3: no rows


def test_sharded_bank_loop_one_shard_matches_local():
    from repro.launch.mesh import make_data_mesh

    K, Q = 32, 4
    batches, bcounts = _keyed_stream(K=K)
    bank = make_bank("rtbs", num_keys=K, n=10, lam=0.1, bcap=8)
    model = make_model("linreg", dim=2)
    local = make_bank_run_loop(bank, model, retrain_every=3,
                               train_keys=range(Q))
    _, _, trace_l = local(jax.random.key(0), batches, bcounts)

    sb, sc = shard_keyed_stream(batches, bcounts, 1, K)
    run = make_sharded_bank_loop(bank, model, make_data_mesh(1),
                                 retrain_every=3, train_keys=range(Q))
    state, params, trace = run(jax.random.key(0), sb, sc)
    assert np.asarray(trace["metric"]).shape[0] == 1  # gathered [S, T]
    np.testing.assert_allclose(np.asarray(trace["metric"])[0],
                               np.asarray(trace_l["metric"]), rtol=1e-6)


def test_sharded_bank_loop_multi_shard_runs():
    """Key-sharded scale-out on every available device (the CI distributed
    job runs this on a real 8-virtual-device mesh): each shard owns a
    contiguous key range with its own local bank; the psum'd metric is
    replicated and finite, reservoirs stay shard-local."""
    from repro.launch.mesh import make_data_mesh

    S = jax.device_count()
    K, Q = 8 * S, 2
    stream = KeyedStream(base=LinRegStream(seed=1), num_keys=K, alpha=1.1,
                         flip_every=4)
    batches, bcounts = materialize_stream(stream, 8, batch_size=4 * S,
                                          fields=("key", "x", "y"))
    sb, sc = shard_keyed_stream(batches, bcounts, S, K)
    bank = make_bank("rtbs", num_keys=K // S, n=6, lam=0.2, bcap=4)
    model = make_model("linreg", dim=2)
    run = make_sharded_bank_loop(bank, model, make_data_mesh(S),
                                 retrain_every=2, train_keys=range(Q))
    state, params, trace = run(jax.random.key(3), sb, sc)
    m = np.asarray(trace["metric"])
    assert m.shape == (S, 8)
    # the psum'd global metric is replicated: every shard logs the same row
    for s in range(1, S):
        np.testing.assert_array_equal(m[0], m[s])
    assert np.isfinite(m[0, 1:]).all()
    assert jax.tree_util.tree_leaves(state.items)[0].shape[0] == S
    assert (np.asarray(state.nfull).sum(axis=1) > 0).any()


def test_shard_keyed_stream_partitions_by_key_ownership():
    K, S = 12, 3
    batches, bcounts = _keyed_stream(K=K, T=5, b=16)
    sb, sc = shard_keyed_stream(batches, bcounts, S, K)
    ks = K // S
    bcap_s = sb["key"].shape[1] // S
    np.testing.assert_array_equal(np.asarray(sc).sum(axis=1),
                                  np.asarray(bcounts))
    for t in range(5):
        seen = []
        for s in range(S):
            c = int(sc[t, s])
            local = np.asarray(sb["key"])[t, s * bcap_s:s * bcap_s + c]
            assert ((0 <= local) & (local < ks)).all()
            seen.append(local + s * ks)
            # payload rides with its key, in arrival order
            x_seg = np.asarray(sb["x"])[t, s * bcap_s:s * bcap_s + c]
            glob = np.asarray(batches["key"])[t, : int(bcounts[t])]
            rows = np.nonzero((glob // ks) == s)[0]
            np.testing.assert_array_equal(
                x_seg, np.asarray(batches["x"])[t, rows]
            )
        got = np.sort(np.concatenate(seen))
        want = np.sort(np.asarray(batches["key"])[t, : int(bcounts[t])])
        np.testing.assert_array_equal(got, want)
    with pytest.raises(ValueError, match="divide"):
        shard_keyed_stream(batches, bcounts, 5, K)
