"""D-R-TBS (paper Sec. 5) validation.

The heavy statistical check runs in a subprocess with 8 forced host devices so
the main pytest process keeps its default single-device jax (smoke tests and
benchmarks must see 1 device; see the dry-run launcher for the 512-device case).
"""
import os
import pathlib
import subprocess
import sys

HERE = pathlib.Path(__file__).parent
SRC = str(HERE.parent / "src")


def _run(script, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # the script sets its own device count
    proc = subprocess.run(
        [sys.executable, str(HERE / script)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_drtbs_8shard_statistics():
    """Theorem 4.2 + size bound + trajectories on a real 8-device mesh."""
    out = _run("_drtbs_stat_check.py")
    assert "statistical checks passed" in out
