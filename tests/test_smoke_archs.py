"""Per-architecture smoke tests (assignment requirement): instantiate a REDUCED
same-family config, run one forward + one train-grad step + one decode step on
CPU; assert output shapes and finiteness. Full configs are exercised only via
the dry-run (ShapeDtypeStruct lowering, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ARCH_IDS, get_config, get_smoke_config
from repro.models import zoo

SEQ = 16
BATCH = 2


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.param_count() > 0
    assert cfg.padded_vocab % 256 == 0
    assert cfg.active_param_count() <= cfg.param_count()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    api = zoo.build(cfg)
    params = api.init_params(jax.random.key(0))
    batch = zoo.make_demo_batch(cfg, jax.random.key(1), BATCH, SEQ)

    logits = jax.jit(api.forward)(params, batch)
    S_total = SEQ if cfg.family != "vlm" else SEQ  # vlm: prefix + text == SEQ
    assert logits.shape[0] == BATCH
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    loss, grads = jax.jit(jax.value_and_grad(api.loss))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # at least some gradient signal
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in flat)
    assert gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    api = zoo.build(cfg)
    params = api.init_params(jax.random.key(0))
    caches = api.init_decode_state(BATCH, max_len=SEQ + 4, prefill_len=0)
    if cfg.family == "audio":
        from repro.models import encdec

        enc_out = encdec.encode(
            cfg, params,
            jax.random.normal(jax.random.key(2), (BATCH, cfg.encoder_seq, cfg.d_model)),
        )
        caches["cross"] = encdec.precompute_cross(cfg, params, enc_out)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    step = jax.jit(api.decode_step)
    for _ in range(3):
        logits, caches = step(params, caches, tok)
        assert logits.shape == (BATCH, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1).astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the training forward's logits
    (same params, same tokens) -- validates cache semantics end-to-end."""
    cfg = get_smoke_config("stablelm_12b")
    api = zoo.build(cfg)
    params = api.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (BATCH, 8), 0, cfg.vocab_size)
    full = api.forward(params, {"tokens": toks})
    caches = api.init_decode_state(BATCH, max_len=12, prefill_len=0)
    outs = []
    for t in range(8):
        logits, caches = api.decode_step(params, caches, toks[:, t : t + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_decode_matches_forward_ssm():
    """Same check for the recurrent (Mamba2) path: chunked SSD == step recurrence."""
    cfg = get_smoke_config("mamba2_370m")
    api = zoo.build(cfg)
    params = api.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(4), (BATCH, 8), 0, cfg.vocab_size)
    full = api.forward(params, {"tokens": toks})
    caches = api.init_decode_state(BATCH, max_len=12, prefill_len=0)
    outs = []
    for t in range(8):
        logits, caches = api.decode_step(params, caches, toks[:, t : t + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        atol=5e-2, rtol=5e-2,
    )


def test_swa_masks_long_context():
    """Sliding-window attention must ignore tokens beyond the receptive field:
    with L layers and window W, position p only sees [p - L*(W-1), p].

    Uses a DENSE config + SWA: MoE capacity dispatch couples tokens globally
    (a perturbed token shifts the sort-based dispatch order), so mixtral's own
    smoke config cannot isolate the attention mask."""
    import dataclasses as _dc

    from repro.configs.stablelm_12b import SMOKE as _base

    cfg = _dc.replace(_base, sliding_window=16)
    S = 48  # receptive field of last pos = 2 layers * 15 = 30 < 47
    api = zoo.build(cfg)
    params = api.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(5), (1, S), 0, cfg.vocab_size)
    logits = api.forward(params, {"tokens": toks})
    # perturb a token outside the last position's receptive field
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab_size)
    logits2 = api.forward(params, {"tokens": toks2})
    last = np.asarray(logits[0, -1], np.float32)
    last2 = np.asarray(logits2[0, -1], np.float32)
    np.testing.assert_allclose(last, last2, atol=1e-4)
    # ...and the full-attention positions DO change (sanity of the probe)
    assert np.abs(np.asarray(logits[0, 1] - logits2[0, 1], np.float32)).max() > 1e-6
