"""Subprocess statistical check for D-R-TBS on an 8-shard host mesh.

Invoked by tests/test_distributed.py with XLA_FLAGS forcing 8 host devices
(pytest's own process keeps the default single device). Validates, on a real
multi-device mesh with uneven/empty per-shard batches:

  * Theorem 4.2 invariant  Pr[i in S_t] = (C_t/W_t) w_t(i)  (Monte Carlo)
  * the global sample-size bound  sum_s nfull_s (+ partial) <= n
  * deterministic W_t / C_t trajectories == the analytic recurrence
  * zero capacity overflow for the sized buffers
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import distributed as dist

S = 8          # shards
CAP_S = 24     # per-shard reservoir capacity
BCAP_S = 8     # per-shard batch capacity
N = 16         # global max sample size
LAM = 0.3
TRIALS = 6000

# global batch sizes per step; deliberately uneven across shards (incl. empty)
GLOBAL_BATCHES = [24, 8, 0, 40, 16, 8, 8, 4]
T = len(GLOBAL_BATCHES)


def split_counts(total, s=S):
    """Deterministic uneven split of `total` items over s shards."""
    base = np.zeros(s, np.int32)
    rs = np.random.RandomState(total * 7 + 13)
    for _ in range(total):
        base[rs.randint(0, max(1, s // 2 + total % s))] += 1  # skewed
    while base.max() > BCAP_S:  # respect per-shard capacity
        src = base.argmax()
        dst = base.argmin()
        base[src] -= 1
        base[dst] += 1
    return base


def main():
    mesh = jax.make_mesh((S,), (dist.AXIS,))
    step = functools.partial(dist.drtbs_shard_step, n=N, lam=LAM)

    def shard_fn(keys, items, nfull, partial, weight, tweight, oflow, bitems, bcnt):
        # per-shard views: items [TRIALS, CAP_S], nfull [TRIALS, 1] -> squeeze
        def one(key, it, nf, pa, w, tw, of, bi, bc):
            st = dist.DRTBSShard(
                items=it, nfull=nf, partial_item=pa, weight=w,
                total_weight=tw, overflow=of,
            )
            st = step(key, st, bi, bc)
            return (st.items, st.nfull, st.partial_item, st.weight,
                    st.total_weight, st.overflow)

        return jax.vmap(one)(
            keys, items, nfull[:, 0], partial, weight, tweight,
            oflow[:, 0], bitems, bcnt[:, 0],
        )

    in_specs = (P(), P(None, dist.AXIS), P(None, dist.AXIS), P(), P(), P(),
                P(None, dist.AXIS), P(None, dist.AXIS), P(None, dist.AXIS))
    out_specs = (P(None, dist.AXIS), P(None, dist.AXIS), P(), P(), P(),
                 P(None, dist.AXIS))

    # shard_map out_specs concatenate per-shard outputs along the spec'd dim;
    # per-shard nfull/overflow are [TRIALS] -> need [TRIALS, 1] locally.
    def fix_dims_post(outs):
        items, nfull, partial, weight, tweight, oflow = outs
        return items, nfull[:, None], partial, weight, tweight, oflow[:, None]

    smapped = jax.jit(
        dist.shard_map(
            lambda *a: fix_dims_post(shard_fn(*a)),
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        )
    )

    # ---- build the stream ----------------------------------------------------
    batch_items = np.zeros((T, TRIALS, S * BCAP_S), np.int32)
    batch_counts = np.zeros((T, TRIALS, S), np.int32)
    for t, g in enumerate(GLOBAL_BATCHES):
        counts = split_counts(g)
        batch_counts[t, :, :] = counts
        nid = 0
        for s in range(S):
            for j in range(counts[s]):
                batch_items[t, :, s * BCAP_S + j] = 1000 * (t + 1) + nid
                nid += 1

    items = jnp.zeros((TRIALS, S * CAP_S), jnp.int32)
    nfull = jnp.zeros((TRIALS, S), jnp.int32)
    partial = jnp.zeros((TRIALS,), jnp.int32)
    weight = jnp.zeros((TRIALS,), jnp.float32)
    tweight = jnp.zeros((TRIALS,), jnp.float32)
    oflow = jnp.zeros((TRIALS, S), jnp.int32)

    w_traj = []
    for t in range(T):
        keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.key(17 + t), i))(
            jnp.arange(TRIALS)
        )
        items, nfull, partial, weight, tweight, oflow = smapped(
            keys, items, nfull, partial, weight, tweight, oflow,
            jnp.asarray(batch_items[t]), jnp.asarray(batch_counts[t]),
        )
        w_traj.append((float(tweight[0]), float(weight[0])))

    # ---- checks ---------------------------------------------------------------
    items_np = np.asarray(items)
    nfull_np = np.asarray(nfull)
    weight_np = np.asarray(weight)
    tw_np = np.asarray(tweight)
    assert int(np.asarray(oflow).sum()) == 0, "capacity overflow"

    # deterministic trajectories
    w = 0.0
    for t, g in enumerate(GLOBAL_BATCHES):
        w = math.exp(-LAM) * w + g
        assert abs(w_traj[t][0] - w) < 1e-3 * max(1.0, w), (t, w_traj[t][0], w)
        assert abs(w_traj[t][1] - min(N, w)) < 1e-3 * max(1.0, w)
    W_T = w
    C_T = min(N, W_T)

    # global bound
    tot_full = nfull_np.sum(axis=1)
    assert (tot_full <= N).all(), tot_full.max()
    assert (np.floor(weight_np + 1e-4) >= tot_full).all()

    # Theorem 4.2: membership per batch (count full items + partial contribution)
    frac = weight_np - np.floor(weight_np)
    rs = np.random.RandomState(0)
    take_partial = rs.rand(TRIALS) < frac
    hits = np.zeros(T + 1)
    # valid-mask per shard slot
    slot = np.arange(S * CAP_S) % CAP_S
    shard = np.arange(S * CAP_S) // CAP_S
    valid = slot < nfull_np[:, shard]
    bidx = np.where(valid, items_np // 1000, 0)
    for t in range(1, T + 1):
        hits[t] = (bidx == t).sum()
    pidx = np.asarray(partial) // 1000
    for t in range(1, T + 1):
        hits[t] += ((pidx == t) & take_partial).sum()

    bad = []
    for j, g in enumerate(GLOBAL_BATCHES):
        if g == 0:
            continue
        emp = hits[j + 1] / TRIALS / g
        expect = (C_T / W_T) * math.exp(-LAM * (T - 1 - j))
        if abs(emp - expect) > 0.03:
            bad.append((j, emp, expect))
    assert not bad, bad

    print("D-R-TBS statistical checks passed:",
          f"W_T={W_T:.3f} C_T={C_T:.3f} trials={TRIALS}")


if __name__ == "__main__":
    main()
