"""The repro.decay subsystem (DESIGN.md Sec. 12):

  * scalar-``lam`` sugar is BIT-identical to ``decay=exponential(lam)`` for
    every registered scheme, local and sharded (the acceptance criterion of
    the subsystem: the sugar constructs the schedule, so this guards the
    construction staying shared);
  * schedule algebra: per-tick factors match the analytic forms, and the
    cumulative-product weights drive R-TBS exactly as Theorem 4.2 predicts
    under POLYNOMIAL decay (the journal extension's generalization);
  * the closed-loop adaptive controller converges on the single-shift
    scenario -- post-shift prequential loss beats every static lambda on the
    grid -- while running inside the jitted (super)batched scan with no
    per-tick re-trace;
  * the delete-complement downsample map satisfies Theorem 4.1 at any
    ``max_deleted`` (fast path AND runtime fallback);
  * ``batch_size_schedule``'s decaying regime floors at one item.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import decay as dk
from repro.core import latent as lt
from repro.core.api import make_sampler
from repro.data.streams import GMMStream, batch_size_schedule, mode_schedule
from repro.manage import make_model, make_run_farm, make_run_loop, \
    materialize_stream

PROTO = jax.ShapeDtypeStruct((), jnp.int32)

LOCAL_DECAYED = {
    "rtbs": dict(n=10),
    "ttbs": dict(n=10, batch_size=8),
    "btbs": dict(cap=64),
}
SHARDED_DECAYED = {
    "drtbs": dict(n=8, cap_s=16),
    "dttbs": dict(n=4, batch_size=4),
}


def _drive(sampler, T=6, b=8, bcap=16, seed=0):
    state = sampler.init(PROTO)
    step = jax.jit(sampler.step)
    for t in range(T):
        items = jnp.full((bcap,), 1000 * (t + 1), jnp.int32) + jnp.arange(bcap)
        state = step(jax.random.fold_in(jax.random.key(seed), t), state,
                     items, jnp.int32(b))
    return state


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# lam sugar == exponential schedule, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", sorted(LOCAL_DECAYED))
def test_lam_sugar_bit_identical_local(scheme):
    lam = 0.3
    a = make_sampler(scheme, lam=lam, **LOCAL_DECAYED[scheme])
    b = make_sampler(scheme, decay=dk.exponential(lam), **LOCAL_DECAYED[scheme])
    sa, sb = _drive(a), _drive(b)
    _assert_trees_equal(sa, sb)
    va = a.extract(jax.random.key(9), sa)
    vb = b.extract(jax.random.key(9), sb)
    _assert_trees_equal(va, vb)
    assert int(a.size(jax.random.key(9), sa)) == int(b.size(jax.random.key(9), sb))
    # and the exponential fast path adds NO schedule state to the pytree
    assert len(jax.tree_util.tree_leaves(sa)) == len(jax.tree_util.tree_leaves(sb))


@pytest.mark.parametrize("scheme", sorted(SHARDED_DECAYED))
def test_lam_sugar_bit_identical_sharded(scheme):
    from jax.sharding import PartitionSpec as P

    from repro.core import distributed as dist

    lam = 0.3
    nsh = jax.device_count()
    mesh = jax.make_mesh((nsh,), (dist.AXIS,))
    bcap_s = 8
    bitems = jnp.arange(4 * nsh * bcap_s, dtype=jnp.int32).reshape(
        4, nsh * bcap_s) + 1
    bcounts = jnp.full((4, nsh), 3, jnp.int32)

    def run_with(sampler):
        def body(key, bitems, bcounts):
            state = sampler.init(PROTO)
            for t in range(4):
                state = sampler.step(jax.random.fold_in(key, t), state,
                                     bitems[t], bcounts[t, 0])
            gview = sampler.extract_global(jax.random.fold_in(key, 9), state)
            return dist.gather_tree(state), gview.items, gview.size[None]

        f = jax.jit(dist.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(None, dist.AXIS), P(None, dist.AXIS)),
            out_specs=(P(), P(), P()),
        ))
        return f(jax.random.key(2), bitems, bcounts)

    a = run_with(make_sampler(scheme, lam=lam, **SHARDED_DECAYED[scheme]))
    b = run_with(make_sampler(scheme, decay=dk.exponential(lam),
                              **SHARDED_DECAYED[scheme]))
    _assert_trees_equal(a, b)


def test_resolve_rejects_ambiguous_decay():
    with pytest.raises(ValueError, match="exactly one"):
        make_sampler("rtbs", n=8)
    with pytest.raises(ValueError, match="exactly one"):
        make_sampler("rtbs", n=8, lam=0.1, decay=dk.exponential(0.1))
    with pytest.raises(TypeError, match="DecaySchedule"):
        make_sampler("rtbs", n=8, decay=0.9)


# ---------------------------------------------------------------------------
# schedule algebra
# ---------------------------------------------------------------------------
def test_schedule_profiles_match_analytic():
    T, beta, t0 = 8, 1.3, 1.0
    prof = np.asarray(dk.decay_profile(dk.polynomial(beta, t0=t0), T))
    want = [(max(t - 1 + t0, 0.0) / (t + t0)) ** beta for t in range(T)]
    np.testing.assert_allclose(prof, want, rtol=1e-6)
    # cumulative products telescope to the power law in arrival time
    D = np.cumprod(prof[1:])  # D_t / D_0 for t >= 1
    np.testing.assert_allclose(
        D, [((t0) / (t + t0)) ** beta for t in range(1, T)], rtol=1e-5
    )

    prof = np.asarray(dk.decay_profile(dk.piecewise((2, 4), (0.1, 0.5, 0.2)), 6))
    want = [math.exp(-v) for v in (0.1, 0.1, 0.5, 0.5, 0.2, 0.2)]
    np.testing.assert_allclose(prof, want, rtol=1e-6)

    prof = np.asarray(dk.decay_profile(
        dk.from_callable(lambda t: jnp.exp(-0.05 * (t + 1.0))), 4))
    np.testing.assert_allclose(
        prof, [math.exp(-0.05 * (t + 1)) for t in range(4)], rtol=1e-6)


def test_schedule_dt_wallclock_gaps():
    """ROADMAP decay follow-up (b): ``tick(dstate, dt=...)`` consumes
    wall-clock gaps. Exponential is exact (e^{-lam dt} for any real dt);
    polynomial's telescoping ratio closes exactly over integral gaps;
    schedules without a native dt form fall back to d^dt (documented)."""
    lam = 0.3
    e = dk.exponential(lam)
    ds = e.init()
    d3, ds3 = e.tick(ds, dt=3.0)
    np.testing.assert_allclose(float(d3), math.exp(-3 * lam), rtol=1e-6)
    assert int(ds3) == 3                    # counter advances by the gap
    d_half, _ = e.tick(ds, dt=0.5)          # fractional gaps: still exact
    np.testing.assert_allclose(float(d_half), math.exp(-0.5 * lam),
                               rtol=1e-6)

    p = dk.polynomial(1.3, t0=1.0)
    ds = p.step(p.step(p.init()))           # counter at t=2
    d2, ds2 = p.tick(ds, dt=2.0)
    # exact telescoping: factor over [t, t+2) == d_t * d_{t+1}
    want = float(p.rate(jnp.int32(2))) * float(p.rate(jnp.int32(3)))
    np.testing.assert_allclose(float(d2), want, rtol=1e-6)
    assert int(ds2) == 4

    c = dk.from_callable(lambda t: jnp.float32(0.9))
    d4, ds4 = c.tick(c.init(), dt=4.0)      # fallback: rate ** dt
    np.testing.assert_allclose(float(d4), 0.9 ** 4, rtol=1e-6)
    assert int(ds4) == 4
    # sub-unit gaps ACCUMULATE (no round-away freeze): 3 gaps of 0.4 move
    # the elapsed-time counter to 1.2, and a time-varying rate moves with it
    ds = p.init()
    for _ in range(3):
        _, ds = p.tick(ds, dt=0.4)
    np.testing.assert_allclose(float(ds), 1.2, rtol=1e-6)
    assert float(p.rate(ds)) != float(p.rate(p.init()))
    # dt=None keeps the historical unit-tick behaviour bit-for-bit
    d1a, s1a = e.tick(e.init())
    d1b, s1b = e.tick(e.init(), dt=None)
    assert float(d1a) == float(d1b) and int(s1a) == int(s1b)


def test_schedule_validation():
    with pytest.raises(ValueError, match="lam >= 0"):
        dk.exponential(-0.1)
    with pytest.raises(ValueError, match="beta >= 0"):
        dk.polynomial(-1.0)
    with pytest.raises(ValueError, match="len"):
        dk.piecewise((2,), (0.1,))
    with pytest.raises(ValueError, match="increasing"):
        dk.piecewise((4, 2), (0.1, 0.2, 0.3))
    assert dk.exponential(0.2).static_rate == pytest.approx(math.exp(-0.2))
    assert dk.polynomial(1.0).static_rate is None


# ---------------------------------------------------------------------------
# Theorem 4.2 under polynomial decay: Pr[i in S_T] = (C_T/W_T) w_T(i),
# with w_T(i) = D_T / D_{t_i} the cumulative-product weight
# ---------------------------------------------------------------------------
def test_rtbs_theorem_4_2_polynomial_decay():
    beta, n, T, b, trials = 1.5, 8, 8, 4, 25000
    sched = dk.polynomial(beta)
    sampler = make_sampler("rtbs", n=n, decay=sched)
    prof = np.asarray(dk.decay_profile(sched, T), np.float64)

    # analytic W_t = d_t W_{t-1} + b and item weights w_T(j) = prod d_{j+1..T-1}
    W = 0.0
    for t in range(T):
        W = prof[t] * W + b
    w_item = [float(np.prod(prof[j + 1:T])) for j in range(T)]
    C = min(n, W)

    bcap = b
    batches = np.zeros((T, bcap), np.int32)
    for t in range(T):
        batches[t] = 1000 * (t + 1) + np.arange(b)
    batches = jnp.asarray(batches)
    bcounts = jnp.full((T,), b, jnp.int32)

    def one(kk):
        state = sampler.init(PROTO)

        def body(state, inp):
            bt, ct, k = inp
            return sampler.step(k, state, bt, ct), None

        keys = jax.random.split(kk, T + 1)
        state, _ = jax.lax.scan(body, state, (batches, bcounts, keys[:T]))
        mask, _ = lt.realize(keys[T], state.inner.lat)
        batch_of = state.inner.lat.items // 1000
        counts = jnp.zeros((T + 1,), jnp.float32)
        counts = counts.at[batch_of].add(mask.astype(jnp.float32))
        return counts[1:], state.inner.lat.weight, state.inner.total_weight

    keys = jax.random.split(jax.random.key(0), trials)
    counts, Cs, Ws = jax.vmap(one)(keys)
    # the scalar trajectories are deterministic and match the analytic ones
    np.testing.assert_allclose(float(Cs[0]), C, rtol=1e-4)
    np.testing.assert_allclose(float(Ws[0]), W, rtol=1e-4)
    probs = np.asarray(counts.mean(axis=0)) / b
    for j in range(T):
        expect = (C / W) * w_item[j]
        assert abs(probs[j] - expect) < 0.02, (j, probs[j], expect)
    # eq.-(1) analogue: relative inclusion is the POLYNOMIAL weight ratio
    # ((t_i + t0) / (t_j + t0))^beta, not an exponential in age
    ratio = probs[2] / probs[5]
    want = w_item[2] / w_item[5]
    assert abs(ratio - want) < 0.12, (ratio, want)


# ---------------------------------------------------------------------------
# delete-complement downsample map: Theorem 4.1 at any max_deleted
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "c,cp,max_deleted",
    [
        (5.6, 3.2, 1),    # deletion count 2-3 > D: runtime fallback path
        (5.6, 3.2, 16),   # fast path, partial cases
        (5.0, 3.4, 4),    # integral C
        (5.6, 5.2, 4),    # kp == k swap case (loop-free)
        (5.6, 0.7, 4),    # kp == 0 corner (loop-free)
        (9.3, 8.9, 2),    # single deletion
    ],
)
def test_downsample_delete_complement_theorem_4_1(c, cp, max_deleted):
    cap, trials = 10, 30000
    k = math.floor(c)
    f = c - k
    ids = jnp.arange(cap, dtype=jnp.int32)
    base = lt.Latent(items=ids, nfull=jnp.int32(k), weight=jnp.float32(c))

    def one(kk):
        k1, k2 = jax.random.split(kk)
        out = lt.downsample(k1, base, jnp.float32(cp),
                            max_deleted=max_deleted)
        mask, _ = lt.realize(k2, out)
        member = jnp.zeros((cap,), jnp.float32)
        member = member.at[out.items].add(mask.astype(jnp.float32))
        return member

    keys = jax.random.split(jax.random.key(3), trials)
    probs = np.asarray(jax.vmap(one)(keys).mean(axis=0))
    scale = cp / c
    for i in range(k):
        assert abs(probs[i] - scale) < 0.015, (i, probs[i], scale)
    if f > 0:
        assert abs(probs[k] - scale * f) < 0.015, (probs[k], scale * f)
    for i in range(k + 1 if f > 0 else k, cap):
        assert probs[i] < 1e-9


# ---------------------------------------------------------------------------
# the closed-loop adaptive controller
# ---------------------------------------------------------------------------
def test_adaptive_beats_best_static_lambda_on_single_shift():
    """The convergence criterion: on the Sec. 6.2 single-shift kNN/GMM
    scenario the controller's post-shift prequential loss beats the endpoint
    of EVERY static lambda on the grid.

    Scenario design (the dial must have no static sweet spot): a sharp
    class-frequency flip (ratio=25) makes stale samples costly, and
    b = 50 << n = 400 makes every fast-flushing static rate run with a
    shrunken steady-state sample (E W = b/(1-e^-lam) < n for lam >= 0.2).
    So the static grid trades pollution against coverage, while the
    controller cruises at lam_min with a full sample, pulses lambda at the
    shift, and anneals back -- getting both.  Margins measured at +0.02..
    +0.035 across 5 stream/key seed combos; the assertion is strict
    inequality against the best of the grid."""
    warm, T, b, n, trials, skip = 30, 40, 50, 400, 8, 3
    grid = (0.005, 0.05, 0.2, 0.5)
    batches, bcounts = materialize_stream(
        GMMStream(seed=0, ratio=25), warm + T, batch_size=b,
        mode=lambda t: 0 if t < warm else 1,
    )
    model = make_model("knn", cap=n + 1, dim=2, k=7, num_classes=100)

    def post_shift_miss(controller, lam):
        sampler = make_sampler("rtbs", n=n, lam=lam)
        farm = make_run_farm(sampler, model, retrain_every=1,
                             controller=controller)
        trace = farm(jax.random.key(11), trials, batches, bcounts)
        return float(np.asarray(trace["metric"])[:, warm + skip:].mean()), trace

    static = {lam: post_shift_miss(None, lam)[0] for lam in grid}
    ctrl = dk.loss_ratio(lam0=0.05, lam_min=0.005, lam_max=0.5)
    adaptive, trace = post_shift_miss(ctrl, 0.05)

    best = min(static.values())
    assert adaptive < best, (adaptive, static)
    # the controller actually moved: lambda pulsed after the shift and came
    # back down once the retrained model recovered
    lam_path = -np.log(np.maximum(np.asarray(trace["decay"]), 1e-30))
    assert lam_path[:, warm:warm + 10].max() > 0.4, lam_path[:, warm:].max()
    assert lam_path[:, -1].mean() < 0.05, lam_path[:, -1]
    # and cruised at lam_min pre-shift (stationary stream -> max sample)
    assert lam_path[:, warm - 5:warm].mean() < 0.01


def test_controller_no_retrace_and_superbatch_bit_identity():
    """The controller runs inside the jitted scan: repeated dispatches hit
    the jit cache (no per-tick or per-call re-trace), and superbatched
    chunking stays bit-identical with the controller in the carry."""
    from repro.data.streams import LinRegStream

    sampler = make_sampler("rtbs", n=40, lam=0.1)
    model = make_model("linreg", dim=2)
    ctrl = dk.loss_ratio(lam0=0.1, lam_min=0.01, lam_max=1.0)
    batches, bcounts = materialize_stream(LinRegStream(seed=0), 12,
                                          batch_size=16)
    r1 = make_run_loop(sampler, model, retrain_every=4, controller=ctrl)
    assert r1 is make_run_loop(sampler, model, retrain_every=4,
                               controller=ctrl)
    assert r1 is not make_run_loop(sampler, model, retrain_every=4)
    out1 = r1(jax.random.key(0), batches, bcounts)
    r1(jax.random.key(1), batches, bcounts)
    assert r1._cache_size() == 1

    r4 = make_run_loop(sampler, model, retrain_every=4, superbatch=4,
                       controller=ctrl)
    out4 = r4(jax.random.key(0), batches, bcounts)
    _assert_trees_equal(out1, out4)
    assert "decay" in out1[2]


def test_controller_rejects_decay_free_schemes():
    model = make_model("linreg", dim=2)
    ctrl = dk.loss_ratio(lam0=0.1, lam_min=0.01, lam_max=1.0)
    for scheme in ("brs", "sw"):
        with pytest.raises(ValueError, match="no decay"):
            make_run_loop(make_sampler(scheme, n=8), model, controller=ctrl)


def test_controller_pulses_relaxes_and_ignores_nan():
    ctrl = dk.loss_ratio(lam0=0.1, lam_min=0.05, lam_max=0.8, warmup=1)
    c = ctrl.init()
    # stationary loss: lambda relaxes to lam_min (max sample)
    for _ in range(10):
        c = ctrl.observe(c, jnp.float32(1.0), jnp.bool_(True))
    assert float(jnp.exp(c.loglam)) == pytest.approx(0.05, rel=1e-5)
    # a loss jump fires ONE pulse straight to lam_max...
    c = ctrl.observe(c, jnp.float32(100.0), jnp.bool_(True))
    assert float(jnp.exp(c.loglam)) == pytest.approx(0.8, rel=1e-5)
    assert int(c.hold) == 8
    # ...and even a sustained plateau cannot keep lambda there: once the
    # slow EMA absorbs the new level the ratio signal dies, the refractory
    # window spaces out the re-fires meanwhile, and the relax leak anneals
    # lambda back down (the stuck-high guard)
    for _ in range(60):
        c = ctrl.observe(c, jnp.float32(100.0), jnp.bool_(True))
    assert float(jnp.exp(c.loglam)) == pytest.approx(0.05, rel=1e-5)
    # NaN losses (empty ticks) change nothing
    c_nan = ctrl.observe(c, jnp.float32(float("nan")), jnp.bool_(True))
    assert float(c_nan.loglam) == float(c.loglam)
    assert int(c_nan.seen) == int(c.seen)
    # non-adjust ticks update the EMAs but never lambda
    c2 = ctrl.observe(c, jnp.float32(500.0), jnp.bool_(False))
    assert float(c2.loglam) == float(c.loglam)
    assert float(c2.fast) != float(c.fast)


def test_adaptive_validation():
    with pytest.raises(ValueError, match="lam_min <= lam0 <= lam_max"):
        dk.loss_ratio(lam0=0.5, lam_min=0.01, lam_max=0.1)
    with pytest.raises(ValueError, match="slow_alpha <= fast_alpha"):
        dk.loss_ratio(lam0=0.1, lam_min=0.01, lam_max=1.0,
                      fast_alpha=0.1, slow_alpha=0.5)


# ---------------------------------------------------------------------------
# streams satellite: one batch_size_schedule branch, floored at 1
# ---------------------------------------------------------------------------
def test_batch_size_schedule_decaying_floors_at_one():
    sizes = [batch_size_schedule("decaying", t, b=100, phi=0.9, t0=0)
             for t in range(200)]
    assert sizes[0] == 100
    assert min(sizes) == 1          # never a permanently-zero bcount tail
    assert sizes[-1] == 1
    assert all(a >= b for a, b in zip(sizes, sizes[1:]))
    grow = [batch_size_schedule("growing", t, b=100, phi=1.002, t0=0)
            for t in range(50)]
    assert grow[0] == 100 and grow[-1] == int(round(100 * 1.002 ** 49))
    with pytest.raises(ValueError):
        batch_size_schedule("nope", 0)


def test_polynomial_decay_in_manage_loop():
    """A time-varying schedule drives the fused loop end to end (wrapped
    state through the scan; mode_schedule sanity on the GMM stream)."""
    stream = GMMStream(seed=1)
    batches, bcounts = materialize_stream(
        stream, 10, batch_size=30,
        mode=lambda t: mode_schedule("periodic", t, delta=3, eta=3))
    n = 80
    sampler = make_sampler("rtbs", n=n, decay=dk.polynomial(1.0))
    model = make_model("knn", cap=n + 1, dim=2, k=3, num_classes=100)
    state, params, trace = make_run_loop(sampler, model, retrain_every=2)(
        jax.random.key(4), batches, bcounts)
    assert isinstance(state, dk.DecayedState)
    assert int(state.dstate) == 10
    m = np.asarray(trace["metric"])
    assert ((m[1:] >= 0) & (m[1:] <= 1)).all()
    assert (np.asarray(trace["size"]) <= n).all()
