"""Subprocess check for the fused sharded manage loop on an 8-shard host mesh.

Invoked by tests/test_sharded_loop.py with XLA_FLAGS forcing 8 host devices
(pytest's own process keeps the default device count). Validates, on a real
multi-device mesh with uneven/empty per-shard batches:

  * fused scan == unfused per-tick shard_map driver, bit-exactly, at 8 shards
  * Theorem 4.2 invariant  Pr[i in S_t] = (C_t/W_t) w_t(i)  on the FINAL
    reservoir of every Monte-Carlo farm trial (the farm vmaps whole fused
    loops inside one shard_map)
  * deterministic W_t / C_t trajectories == the analytic recurrence, and the
    per-tick size trace stays in {floor(C_t), floor(C_t)+1}
  * the global sample-size bound and zero capacity overflow
  * the fractional item is materialized whenever counted: the model's fit
    (which receives extract_global's view on retrain ticks) returns
    view.mask.sum(), which must equal the logged size for every trial
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import math  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.api import make_sampler  # noqa: E402
from repro.launch.mesh import make_data_mesh  # noqa: E402
from repro.manage import (  # noqa: E402
    init_sharded_state,
    make_sharded_manage_step,
    make_sharded_run_farm,
    make_sharded_run_loop,
)
from repro.manage.models import ModelAdapter  # noqa: E402

S = 8          # shards
CAP_S = 32     # per-shard reservoir capacity
BCAP_S = 8     # per-shard batch capacity
N = 40         # global max sample size: the stream saturates mid-run and
#                undershoots again, so the FINAL C = W_T is fractional and
#                the farm exercises the reserved partial-item slot
LAM = 0.3
TRIALS = 4000
RETRAIN_EVERY = 2

# global batch sizes per tick; deliberately uneven across shards (incl. empty)
GLOBAL_BATCHES = [24, 8, 0, 40, 16, 8, 8, 4]
T = len(GLOBAL_BATCHES)


def split_counts(total, s=S):
    """Deterministic uneven split of `total` items over s shards."""
    base = np.zeros(s, np.int32)
    rs = np.random.RandomState(total * 7 + 13)
    for _ in range(total):
        base[rs.randint(0, max(1, s // 2 + total % s))] += 1  # skewed
    while base.max() > BCAP_S:  # respect per-shard capacity
        src = base.argmax()
        dst = base.argmin()
        base[src] -= 1
        base[dst] += 1
    return base


def probe_model():
    """Item-type-agnostic adapter: ``fit`` returns the GLOBAL view's
    mask.sum(), so the final params witness that the fractional item's
    payload is selected exactly when it is counted."""
    return ModelAdapter(
        name="probe",
        init=lambda: jnp.float32(-1.0),
        fit=lambda key, params, view: jnp.sum(view.mask).astype(jnp.float32),
        evaluate=lambda params, batch, bcount: jnp.float32(0.0),
        hyper={"probe": True},
    )


def build_stream():
    batch_items = np.zeros((T, S * BCAP_S), np.int32)
    batch_counts = np.zeros((T, S), np.int32)
    for t, g in enumerate(GLOBAL_BATCHES):
        counts = split_counts(g)
        batch_counts[t] = counts
        nid = 0
        for s in range(S):
            for j in range(counts[s]):
                batch_items[t, s * BCAP_S + j] = 1000 * (t + 1) + nid
                nid += 1
    return jnp.asarray(batch_items), jnp.asarray(batch_counts)


def main():
    mesh = make_data_mesh(S)
    sampler = make_sampler("drtbs", n=N, lam=LAM, cap_s=CAP_S)
    model = probe_model()
    batches, bcounts = build_stream()

    # ---- fused == per-tick, bit-exactly, on the real 8-shard mesh ---------
    key = jax.random.key(11)
    run = make_sharded_run_loop(sampler, model, mesh,
                                retrain_every=RETRAIN_EVERY)
    state_f, params_f, trace_f = run(key, batches, bcounts)

    tick = make_sharded_manage_step(sampler, model, mesh,
                                    retrain_every=RETRAIN_EVERY)
    state = init_sharded_state(sampler, S, jax.ShapeDtypeStruct((), jnp.int32))
    params = model.init()
    metrics, sizes = [], []
    for t in range(T):
        state, params, m = tick(key, jnp.int32(t), state, params,
                                batches[t], bcounts[t])
        metrics.append(np.asarray(m["metric"]))
        sizes.append(np.asarray(m["size"]))
    np.testing.assert_array_equal(np.asarray(trace_f["metric"]),
                                  np.asarray(metrics))
    np.testing.assert_array_equal(np.asarray(trace_f["size"]),
                                  np.asarray(sizes))
    for a, b in zip(jax.tree_util.tree_leaves(state_f),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(params_f), np.asarray(params))

    # ---- Monte-Carlo farm ----------------------------------------------------
    farm = make_sharded_run_farm(sampler, model, mesh,
                                 retrain_every=RETRAIN_EVERY)
    states, params, trace = farm(jax.random.key(17), TRIALS, batches, bcounts)

    items_np = np.asarray(states.items)          # [TRIALS, S, CAP_S]
    nfull_np = np.asarray(states.nfull)          # [TRIALS, S]
    partial_np = np.asarray(states.partial_item)[:, 0]  # replicated
    weight_np = np.asarray(states.weight)[:, 0]
    tw_np = np.asarray(states.total_weight)[:, 0]
    size_np = np.asarray(trace["size"])          # [TRIALS, T]
    params_np = np.asarray(params)               # [TRIALS]

    assert int(np.asarray(states.overflow).sum()) == 0, "capacity overflow"

    # deterministic trajectories + per-tick size in {floor(C_t), floor(C_t)+1}
    w = 0.0
    for t, g in enumerate(GLOBAL_BATCHES):
        w = math.exp(-LAM) * w + g
        c = min(N, w)
        lo, hi = math.floor(c), math.floor(c) + 1
        assert ((size_np[:, t] >= lo) & (size_np[:, t] <= hi)).all(), (
            t, c, size_np[:, t].min(), size_np[:, t].max())
    W_T = w
    C_T = min(N, W_T)
    assert (np.abs(tw_np - W_T) < 1e-3 * max(1.0, W_T)).all()
    assert (np.abs(weight_np - C_T) < 1e-3 * max(1.0, C_T)).all()

    # global bound
    tot_full = nfull_np.sum(axis=1)
    assert (tot_full <= N).all(), tot_full.max()
    assert (np.floor(weight_np + 1e-4) >= tot_full).all()

    # the fit on the LAST retrain tick saw a view with mask.sum() == size
    last_fit = max(t for t in range(T) if (t + 1) % RETRAIN_EVERY == 0)
    np.testing.assert_array_equal(params_np,
                                  size_np[:, last_fit].astype(np.float32))

    # Theorem 4.2: membership per batch over the farm's final reservoirs
    frac = weight_np - np.floor(weight_np)
    rs = np.random.RandomState(0)
    take_partial = rs.rand(TRIALS) < frac
    slot_valid = (np.arange(CAP_S)[None, None, :] < nfull_np[:, :, None])
    bidx = np.where(slot_valid, items_np // 1000, 0)
    hits = np.zeros(T + 1)
    for t in range(1, T + 1):
        hits[t] = (bidx == t).sum()
    pidx = partial_np // 1000
    for t in range(1, T + 1):
        hits[t] += ((pidx == t) & take_partial).sum()

    bad = []
    for j, g in enumerate(GLOBAL_BATCHES):
        if g == 0:
            continue
        emp = hits[j + 1] / TRIALS / g
        expect = (C_T / W_T) * math.exp(-LAM * (T - 1 - j))
        if abs(emp - expect) > 0.03:
            bad.append((j, emp, expect))
    assert not bad, bad

    print("sharded-loop checks passed:",
          f"W_T={W_T:.3f} C_T={C_T:.3f} trials={TRIALS}")


if __name__ == "__main__":
    main()
