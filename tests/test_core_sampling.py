"""Statistical validation of the core TBS algorithms against the paper's claims.

Every test here checks an *analytic* property from the paper (inclusion
probabilities, sample-size moments, uniformity), by Monte Carlo over vmapped
trials, for BOTH the fixed-shape JAX implementations and (where cheap) the
paper-literal Python references.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import latent as lt
from repro.core import ref, rng, rtbs, simple

PROTO = jax.ShapeDtypeStruct((), jnp.int32)


def keys(seed, num):
    return jax.random.split(jax.random.key(seed), num)


# --------------------------------------------------------------------------
# rng primitives
# --------------------------------------------------------------------------
class TestRng:
    def test_hypergeometric_pmf(self):
        k, a, b = 7, 10, 15
        draws = jax.vmap(
            lambda kk: rng.hypergeometric(kk, k, a, b, max_support=32)
        )(keys(0, 40000))
        draws = np.asarray(draws)
        # analytic pmf
        from math import comb

        support = range(max(0, k - b), min(a, k) + 1)
        pmf = {x: comb(a, x) * comb(b, k - x) / comb(a + b, k) for x in support}
        for x, p in pmf.items():
            emp = float(np.mean(draws == x))
            assert abs(emp - p) < 0.012, (x, emp, p)
        assert draws.min() >= max(0, k - b) and draws.max() <= min(a, k)

    def test_hypergeometric_edges(self):
        kk = jax.random.key(1)
        assert int(rng.hypergeometric(kk, 0, 5, 5, max_support=16)) == 0
        assert int(rng.hypergeometric(kk, 10, 10, 0, max_support=16)) == 10
        assert int(rng.hypergeometric(kk, 5, 0, 9, max_support=16)) == 0

    def test_multivariate_hypergeometric(self):
        counts = jnp.array([3, 0, 7, 5], jnp.int32)
        k = 9
        draws = jax.vmap(
            lambda kk: rng.multivariate_hypergeometric(kk, k, counts, max_support=16)
        )(keys(2, 20000))
        draws = np.asarray(draws)
        assert (draws.sum(axis=1) == k).all()
        assert (draws <= np.asarray(counts)).all()
        mean = draws.mean(axis=0)
        expect = k * np.asarray(counts) / float(counts.sum())
        np.testing.assert_allclose(mean, expect, atol=0.05)

    def test_stochastic_round(self):
        x = 3.6
        draws = jax.vmap(lambda kk: rng.stochastic_round(kk, x))(keys(3, 20000))
        draws = np.asarray(draws)
        assert set(np.unique(draws)) <= {3, 4}
        assert abs(draws.mean() - x) < 0.02

    def test_prefix_permutation(self):
        cap, nvalid = 12, 7
        perms = jax.vmap(lambda kk: rng.prefix_permutation(kk, cap, nvalid))(
            keys(4, 8000)
        )
        perms = np.asarray(perms)
        # first nvalid entries are a permutation of range(nvalid)
        head = np.sort(perms[:, :nvalid], axis=1)
        assert (head == np.arange(nvalid)).all()
        # uniform marginal of the first element
        for v in range(nvalid):
            emp = float(np.mean(perms[:, 0] == v))
            assert abs(emp - 1 / nvalid) < 0.02


# --------------------------------------------------------------------------
# downsampling (paper Algorithm 3 / Theorem 4.1)
# --------------------------------------------------------------------------
def _downsample_inclusion(c, cp, trials=30000, seed=0):
    """Empirical Pr[id in S'] after downsample(c -> cp), for the JAX impl."""
    cap = 10
    k = math.floor(c)
    ids = jnp.arange(cap, dtype=jnp.int32)  # slot i holds id i
    base = lt.Latent(items=ids, nfull=jnp.int32(k), weight=jnp.float32(c))

    def one(kk):
        k1, k2 = jax.random.split(kk)
        out = lt.downsample(k1, base, jnp.float32(cp))
        mask, _ = lt.realize(k2, out)
        member = jnp.zeros((cap,), jnp.float32)
        member = member.at[out.items].add(mask.astype(jnp.float32))
        return member

    member = jax.vmap(one)(keys(seed, trials))
    return np.asarray(member.mean(axis=0))


@pytest.mark.parametrize(
    "c,cp",
    [
        (5.0, 3.4),   # integral C, 0<kp<k, partial created
        (5.6, 3.2),   # partial exists, 0<kp<k
        (5.6, 5.2),   # kp == k (no deletion, swap case)
        (5.6, 0.7),   # kp == 0 corner
        (5.6, 3.0),   # fp == 0 (no partial in result)
        (1.7, 0.4),   # tiny sample
        (4.0, 4.0),   # identity
    ],
)
def test_downsample_theorem_4_1(c, cp):
    """Every item's inclusion prob is scaled by exactly C'/C (Theorem 4.1)."""
    k = math.floor(c)
    f = c - k
    probs = _downsample_inclusion(c, cp)
    scale = cp / c
    for i in range(k):  # full items: Pr was 1
        assert abs(probs[i] - scale) < 0.015, (i, probs[i], scale)
    if f > 0:  # partial item: Pr was frac(c)
        assert abs(probs[k] - scale * f) < 0.015, (probs[k], scale * f)
    # nothing else should ever appear
    for i in range(k + 1 if f > 0 else k, 10):
        assert probs[i] < 1e-9


def test_ref_downsample_theorem_4_1():
    """Same check for the paper-literal Python reference."""
    import random

    c, cp = 5.6, 3.2
    k, f = 5, c - 5
    hits = np.zeros(7)
    trials = 30000
    rnd = random.Random(0)
    for _ in range(trials):
        latr = ref.RefLatent(full=list(range(5)), partial=5, weight=c)
        out = ref.ref_downsample(rnd, latr, cp)
        for it in out.realize(rnd):
            hits[it] += 1
    probs = hits / trials
    scale = cp / c
    np.testing.assert_allclose(probs[:5], scale, atol=0.02)
    assert abs(probs[5] - scale * f) < 0.02


# --------------------------------------------------------------------------
# R-TBS (paper Algorithm 2): Theorem 4.2 invariant + eq. (1)
# --------------------------------------------------------------------------
def _analytic_w(batch_sizes, lam):
    """W_t = sum_j B_j e^{-lam (t-j)} (deterministic)."""
    w = 0.0
    out = []
    for b in batch_sizes:
        w = math.exp(-lam) * w + b
        out.append(w)
    return out


def _rtbs_membership(batch_sizes, lam, n, trials, seed=0):
    """Run R-TBS over the stream; return empirical Pr[item-of-batch-j in S_T]
    (items within one batch are exchangeable, so we average over the batch).

    Item ids encode their batch: id = 1000*(t+1) + j.
    """
    T = len(batch_sizes)
    bcap = max(batch_sizes)
    batches = np.zeros((T, bcap), np.int32)
    for t, b in enumerate(batch_sizes):
        batches[t, :b] = 1000 * (t + 1) + np.arange(b)
    batches = jnp.asarray(batches)
    bcounts = jnp.asarray(batch_sizes, jnp.int32)

    def one(kk):
        st = rtbs.init(PROTO, n)
        k_run, k_real = jax.random.split(kk)
        st, _ = rtbs.run_stream(k_run, st, batches, bcounts, n=n, lam=lam)
        mask, _ = lt.realize(k_real, st.lat)
        # per-batch membership count
        batch_of = st.lat.items // 1000  # 0 for empty slots
        counts = jnp.zeros((T + 1,), jnp.float32)
        counts = counts.at[batch_of].add(mask.astype(jnp.float32))
        return counts[1:]

    counts = jax.vmap(one)(keys(seed, trials))
    mean_counts = np.asarray(counts.mean(axis=0))
    return mean_counts / np.maximum(np.asarray(batch_sizes), 1)


@pytest.mark.parametrize(
    "batch_sizes,lam,n",
    [
        ([4, 4, 4, 4, 4, 4, 4, 4], 0.3, 8),        # saturates quickly
        ([2, 2, 2, 2, 2, 2], 0.4, 16),             # never saturates
        ([12, 0, 0, 3, 9, 1, 5, 7], 0.5, 8),       # wild rates: sat<->unsat flips
        ([6, 6, 0, 0, 0, 0, 6, 2], 0.8, 8),        # heavy decay, undershoots
    ],
)
def test_rtbs_theorem_4_2(batch_sizes, lam, n):
    """Pr[i in S_t] == (C_t/W_t) w_t(i) for every batch age (Theorem 4.2)."""
    T = len(batch_sizes)
    ws = _analytic_w(batch_sizes, lam)
    W_T = ws[-1]
    C_T = min(n, W_T)
    probs = _rtbs_membership(batch_sizes, lam, n, trials=25000)
    for j, b in enumerate(batch_sizes):
        if b == 0:
            continue
        w_item = math.exp(-lam * (T - 1 - j))
        expect = (C_T / W_T) * w_item
        assert abs(probs[j] - expect) < 0.02, (j, probs[j], expect)


def test_rtbs_eq_1_relative_inclusion():
    """Pr[i in S]/Pr[j in S] == e^{-lam (t_j - t_i)} for all batch pairs (eq. (1))."""
    batch_sizes = [5, 5, 5, 5, 5, 5]
    lam, n = 0.35, 10
    probs = _rtbs_membership(batch_sizes, lam, n, trials=40000)
    for j in range(len(batch_sizes) - 1):
        ratio = probs[j] / probs[j + 1]
        assert abs(ratio - math.exp(-lam)) < 0.06, (j, ratio)


def test_rtbs_scalar_trajectories_match_ref():
    """C_t, W_t are deterministic; JAX and paper-literal ref must agree exactly."""
    batch_sizes = [3, 9, 0, 2, 14, 0, 0, 1, 6, 8]
    lam, n = 0.25, 8
    r = ref.RefRTBS(n=n, lam=lam, seed=1)
    ref_c, ref_w = [], []
    for t, b in enumerate(batch_sizes):
        r.step([1000 * (t + 1) + j for j in range(b)])
        ref_c.append(r.lat.weight)
        ref_w.append(r.W)

    bcap = max(batch_sizes)
    batches = np.zeros((len(batch_sizes), bcap), np.int32)
    for t, b in enumerate(batch_sizes):
        batches[t, :b] = 1
    st = rtbs.init(PROTO, n)
    st, trace = rtbs.run_stream(
        jax.random.key(0),
        st,
        jnp.asarray(batches),
        jnp.asarray(batch_sizes, jnp.int32),
        n=n,
        lam=lam,
    )
    np.testing.assert_allclose(np.asarray(trace["C"]), ref_c, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(trace["W"]), ref_w, rtol=1e-5)


def test_rtbs_never_exceeds_n():
    batch_sizes = [20, 1, 17, 0, 30, 2, 2, 25]
    n = 8

    def one(kk):
        st = rtbs.init(PROTO, n)
        bcap = max(batch_sizes)
        batches = np.zeros((len(batch_sizes), bcap), np.int32)
        for t, b in enumerate(batch_sizes):
            batches[t, :b] = 1
        k_run, k_real = jax.random.split(kk)
        st, _ = rtbs.run_stream(
            k_run,
            st,
            jnp.asarray(batches),
            jnp.asarray(batch_sizes, jnp.int32),
            n=n,
            lam=0.2,
        )
        _, size = rtbs.realize(k_real, st)
        return size

    sizes = np.asarray(jax.vmap(one)(keys(7, 2000)))
    assert sizes.max() <= n


def test_ref_rtbs_theorem_4_2():
    """Paper-literal reference satisfies the same invariant (independent MC)."""
    batch_sizes = [4, 4, 4, 4, 4, 4]
    lam, n = 0.3, 8
    T = len(batch_sizes)
    ws = _analytic_w(batch_sizes, lam)
    C_T, W_T = min(n, ws[-1]), ws[-1]
    hits = np.zeros(T)
    trials = 12000
    for tr in range(trials):
        r = ref.RefRTBS(n=n, lam=lam, seed=tr)
        for t, b in enumerate(batch_sizes):
            r.step([1000 * (t + 1) + j for j in range(b)])
        for it in r.sample():
            hits[it // 1000 - 1] += 1
    probs = hits / trials / np.asarray(batch_sizes)
    for j in range(T):
        expect = (C_T / W_T) * math.exp(-lam * (T - 1 - j))
        assert abs(probs[j] - expect) < 0.025, (j, probs[j], expect)


# --------------------------------------------------------------------------
# T-TBS (paper Algorithm 1 / Theorem 3.1)
# --------------------------------------------------------------------------
def test_ttbs_mean_size_theorem_3_1_ii():
    """E[C_t] = n + p^t (C_0 - n); with C_0=0 and t large, E[C_t] -> n."""
    n, lam, b = 12, 0.3, 8
    p = math.exp(-lam)
    q = n * (1 - p) / b
    assert q <= 1
    T, trials, bcap, cap = 30, 4000, 8, 64

    batches = jnp.ones((T, bcap), jnp.int32)
    bcounts = jnp.full((T,), b, jnp.int32)

    def one(kk):
        st = simple.init(PROTO, cap)

        def body(carry, inp):
            st = carry
            items_t, cnt_t, key_t = inp
            st = simple.ttbs_step(
                key_t, st, items_t, cnt_t, p=jnp.float32(p), q=jnp.float32(q)
            )
            return st, st.count

        st, csizes = jax.lax.scan(body, st, (batches, bcounts, jax.random.split(kk, T)))
        return csizes, st.overflow

    csizes, overflow = jax.vmap(one)(keys(11, trials))
    csizes = np.asarray(csizes, np.float64)
    assert int(np.asarray(overflow).sum()) == 0  # cap chosen large enough
    for t in [4, 9, 19, 29]:
        expect = n + (p ** (t + 1)) * (0 - n)
        emp = csizes[:, t].mean()
        assert abs(emp - expect) < 0.35, (t, emp, expect)


def test_ttbs_eq1_inclusion():
    """T-TBS item inclusion: Pr[x in S_t'] = q e^{-lam (t'-t)} (Sec. 3)."""
    n, lam, b = 6, 0.4, 10
    p = math.exp(-lam)
    q = n * (1 - p) / b
    T, trials, bcap, cap = 6, 30000, 10, 64
    batches = np.zeros((T, bcap), np.int32)
    for t in range(T):
        batches[t, :b] = 1000 * (t + 1) + np.arange(b)
    batches = jnp.asarray(batches)
    bcounts = jnp.full((T,), b, jnp.int32)

    def one(kk):
        st = simple.init(PROTO, cap)

        def body(carry, inp):
            st = carry
            items_t, cnt_t, key_t = inp
            return (
                simple.ttbs_step(
                    key_t, st, items_t, cnt_t, p=jnp.float32(p), q=jnp.float32(q)
                ),
                None,
            )

        st, _ = jax.lax.scan(body, st, (batches, bcounts, jax.random.split(kk, T)))
        mask = jnp.arange(cap) < st.count
        batch_of = st.items // 1000
        counts = jnp.zeros((T + 1,), jnp.float32).at[batch_of].add(
            mask.astype(jnp.float32)
        )
        return counts[1:]

    counts = np.asarray(jax.vmap(one)(keys(12, trials)).mean(axis=0))
    probs = counts / b
    for j in range(T):
        expect = q * math.exp(-lam * (T - 1 - j))
        assert abs(probs[j] - expect) < 0.015, (j, probs[j], expect)


# --------------------------------------------------------------------------
# B-RS uniformity + SW semantics
# --------------------------------------------------------------------------
def test_brs_uniform_inclusion():
    n = 6
    batch_sizes = [4, 7, 2, 9, 3]
    total = sum(batch_sizes)
    T, bcap, cap = len(batch_sizes), max(batch_sizes), 8
    batches = np.zeros((T, bcap), np.int32)
    for t, b in enumerate(batch_sizes):
        batches[t, :b] = 1000 * (t + 1) + np.arange(b)
    batches = jnp.asarray(batches)
    bcounts = jnp.asarray(batch_sizes, jnp.int32)

    def one(kk):
        st = simple.init(PROTO, cap)

        def body(carry, inp):
            st = carry
            items_t, cnt_t, key_t = inp
            return simple.brs_step(key_t, st, items_t, cnt_t, n=n), None

        st, _ = jax.lax.scan(body, st, (batches, bcounts, jax.random.split(kk, T)))
        mask = jnp.arange(cap) < st.count
        batch_of = st.items // 1000
        counts = jnp.zeros((T + 1,), jnp.float32).at[batch_of].add(
            mask.astype(jnp.float32)
        )
        return counts[1:], st.count

    counts, csize = jax.vmap(one)(keys(13, 30000))
    assert (np.asarray(csize) == n).all()
    probs = np.asarray(counts.mean(axis=0)) / np.asarray(batch_sizes)
    np.testing.assert_allclose(probs, n / total, atol=0.02)


def test_sliding_window_exact():
    n, bcap, cap = 5, 4, 8
    batch_sizes = [3, 4, 2, 4]
    T = len(batch_sizes)
    batches = np.zeros((T, bcap), np.int32)
    nid = 1
    order = []
    for t, b in enumerate(batch_sizes):
        for j in range(b):
            batches[t, j] = nid
            order.append(nid)
            nid += 1
    st = simple.init(PROTO, cap)
    for t in range(T):
        st = simple.sw_step(
            jax.random.key(t),
            st,
            jnp.asarray(batches[t]),
            jnp.int32(batch_sizes[t]),
            n=n,
        )
    got = sorted(np.asarray(st.items)[: int(st.count)].tolist())
    assert got == sorted(order[-n:])


# --------------------------------------------------------------------------
# B-Chao: reproduce the paper's Appendix-D claim that eq. (1) is violated
# --------------------------------------------------------------------------
def test_bchao_violates_eq1_during_fillup():
    """During fill-up every arriving item is kept w.p. 1, so the inclusion-prob
    ratio between consecutive batches is 1 instead of e^{-lam} (Appendix D)."""
    lam, n = 0.5, 12
    trials = 4000
    hits = np.zeros(2)
    for tr in range(trials):
        c = ref.RefBChao(n=n, lam=lam, seed=tr)
        c.step([100 + j for j in range(4)])
        c.step([200 + j for j in range(4)])  # still filling up: 8 < 12
        s = c.sample()
        hits[0] += sum(1 for x in s if 100 <= x < 200)
        hits[1] += sum(1 for x in s if 200 <= x < 300)
    probs = hits / trials / 4
    ratio = probs[0] / probs[1]
    # B-Chao keeps everything during fill-up: ratio == 1, violating e^{-0.5}=0.61
    assert abs(ratio - 1.0) < 0.05
    assert abs(ratio - math.exp(-lam)) > 0.25


def test_bchao_respects_capacity():
    c = ref.RefBChao(n=5, lam=0.2, seed=0)
    for t in range(10):
        c.step([t * 100 + j for j in range(7)])
        assert len(c.sample()) <= 5
