"""The fused sampler hot path (DESIGN.md Sec. 11): kernel parity, argsort-free
RNG quality, fused-vs-reference R-TBS equivalence, superbatched manage loop.

Validation chain for the Pallas path on CPU CI:

  1. the ``tbs_step`` kernel body (interpret mode) == the jnp oracle on
     randomized (cap, bcap, D, dtype) grids -- the payload pass is exact;
  2. a multi-tick R-TBS stream driven with ``impl="interpret"`` (kernel body)
     is BIT-IDENTICAL to ``impl="ref"`` (the default off-TPU route) -- the
     fused step's states don't depend on the apply implementation;
  3. the Theorem 4.1/4.2 statistical checks run against the fused step
     (tests/test_core_sampling.py exercises them on the default route; a
     re-run lives here as an explicit marker). By (1)+(2) those guarantees
     extend verbatim to the compiled Pallas route.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import latent as lt
from repro.core import rng, rtbs
from repro.core.api import SampleView, make_sampler, materialize_view
from repro.kernels.tbs_step import ops as ts_ops, ref as ts_ref

PROTO = jax.ShapeDtypeStruct((), jnp.int32)


def keys(seed, num):
    return jax.random.split(jax.random.key(seed), num)


def _id_stream(batch_sizes, bcap):
    T = len(batch_sizes)
    batches = np.zeros((T, bcap), np.int32)
    for t, b in enumerate(batch_sizes):
        batches[t, :b] = 1000 * (t + 1) + np.arange(b)
    return jnp.asarray(batches), jnp.asarray(batch_sizes, jnp.int32)


# ---------------------------------------------------------------------------
# 1. kernel parity (interpret mode executes the kernel body on CPU)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "cap,bcap,D,block,dtype",
    [
        (128, 32, 8, 64, jnp.float32),
        (256, 64, 4, 128, jnp.int32),
        (65, 16, 8, 64, jnp.float32),     # cap not a block multiple (padded)
        (128, 128, 16, 32, jnp.bfloat16),
        (33, 8, 1, 128, jnp.int32),       # n+1-style odd cap, scalar payload
    ],
)
def test_tbs_step_kernel_matches_ref(cap, bcap, D, block, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    if dtype == jnp.int32:
        items = jax.random.randint(k1, (cap, D), 0, 10**6, jnp.int32)
        batch = jax.random.randint(k2, (bcap, D), 0, 10**6, jnp.int32)
    else:
        items = jax.random.normal(k1, (cap, D), dtype)
        batch = jax.random.normal(k2, (bcap, D), dtype)
    # random two-source map: mixes reservoir rows and batch rows
    src = jax.random.randint(k3, (cap,), 0, cap + bcap, jnp.int32)
    got = ts_ops.tbs_step_apply(items, batch, src, block=block,
                                impl="interpret")
    want = ts_ref.apply_ref(items, batch, src)
    np.testing.assert_array_equal(
        np.asarray(got, np.float32), np.asarray(want, np.float32)
    )


def test_tbs_step_apply_pytree_and_dtypes():
    """The ops wrapper flattens arbitrary leaf shapes and widens bool/int8."""
    cap, bcap = 16, 4
    items = {
        "x": jnp.arange(cap * 6, dtype=jnp.float32).reshape(cap, 2, 3),
        "y": jnp.arange(cap, dtype=jnp.int8),
        "m": jnp.zeros((cap,), bool),
    }
    batch = {
        "x": -jnp.ones((bcap, 2, 3), jnp.float32),
        "y": -jnp.ones((bcap,), jnp.int8),
        "m": jnp.ones((bcap,), bool),
    }
    src = jnp.array([cap, cap + 1, 0, 5] + list(range(4, cap)), jnp.int32)
    out = ts_ops.tbs_step_apply(items, batch, src, impl="ref")
    assert out["y"].dtype == jnp.int8 and out["m"].dtype == bool
    np.testing.assert_array_equal(np.asarray(out["y"][:4]), [-1, -1, 0, 5])
    assert bool(out["m"][0]) and not bool(out["m"][2])
    np.testing.assert_array_equal(np.asarray(out["x"][2]),
                                  np.asarray(items["x"][0]))


# ---------------------------------------------------------------------------
# 2. argsort-free RNG: same structural contract as prefix_permutation
# ---------------------------------------------------------------------------
class TestPrefixPermutationFast:
    def test_structure(self):
        cap, nvalid = 12, 7
        perms = jax.vmap(
            lambda kk: rng.prefix_permutation_fast(kk, cap, nvalid)
        )(keys(4, 8000))
        perms = np.asarray(perms)
        head = np.sort(perms[:, :nvalid], axis=1)
        assert (head == np.arange(nvalid)).all()
        assert (perms[:, nvalid:] == np.arange(nvalid, cap)).all()
        for v in range(nvalid):
            emp = float(np.mean(perms[:, 0] == v))
            assert abs(emp - 1 / nvalid) < 0.02

    def test_tiny_domain_marginals(self):
        """Swap-or-not bias on a 3-element domain stays below MC noise."""
        n = 3
        perms = np.asarray(
            jax.vmap(lambda kk: rng.prefix_permutation_fast(kk, 4, n))(
                keys(9, 60000)
            )
        )
        for pos in range(n):
            for v in range(n):
                emp = float(np.mean(perms[:, pos] == v))
                assert abs(emp - 1 / n) < 0.01, (pos, v, emp)

    def test_prefix_k(self):
        """k-prefix evaluation agrees with the full evaluation."""
        cap, nvalid, k = 64, 50, 16
        kk = jax.random.key(3)
        full = rng.prefix_permutation_fast(kk, cap, nvalid)
        pre = rng.prefix_permutation_fast(kk, cap, nvalid, k=k)
        np.testing.assert_array_equal(np.asarray(full[:k]), np.asarray(pre))

    def test_traced_n_jit(self):
        f = jax.jit(lambda kk, n: rng.prefix_permutation_fast(kk, 32, n))
        out = np.asarray(f(jax.random.key(0), jnp.int32(10)))
        assert sorted(out[:10].tolist()) == list(range(10))
        assert (out[10:] == np.arange(10, 32)).all()
        assert sorted(np.asarray(f(jax.random.key(0), jnp.int32(0))).tolist()) \
            == list(range(32))


# ---------------------------------------------------------------------------
# 3. fused step vs reference step
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "batch_sizes,lam,n",
    [
        ([12, 0, 0, 3, 9, 1, 5, 7, 16, 2, 0, 8], 0.07, 8),
        ([4, 4, 4, 4, 4, 4, 4, 4], 0.3, 8),
        ([6, 6, 0, 0, 0, 0, 6, 2], 0.8, 8),       # heavy decay, undershoots
        ([16, 16, 16, 16, 16, 16], 0.1, 24),      # saturates, stays saturated
    ],
)
def test_fused_matches_ref_scalars_and_validity(batch_sizes, lam, n):
    """C_t/W_t trajectories are deterministic: fused and reference agree
    exactly; and the fused valid region only ever holds genuinely streamed,
    distinct items (no fabrication, no duplication)."""
    bcap = max(batch_sizes)
    batches, bcounts = _id_stream(batch_sizes, bcap)
    st0 = rtbs.init(PROTO, n)
    fin_f, tr_f = rtbs.run_stream(jax.random.key(0), st0, batches, bcounts,
                                  n=n, lam=lam)
    fin_r, tr_r = rtbs.run_stream(jax.random.key(0), st0, batches, bcounts,
                                  n=n, lam=lam, use_ref=True)
    np.testing.assert_allclose(np.asarray(tr_f["C"]), np.asarray(tr_r["C"]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(tr_f["W"]), np.asarray(tr_r["W"]),
                               rtol=1e-5)
    k = int(fin_f.lat.nfull)
    live = k + (1 if float(fin_f.lat.weight) % 1.0 > 1e-5 else 0)
    got = np.asarray(fin_f.lat.items)[:live]
    T = len(batch_sizes)
    assert ((got >= 1000) & (got < 1000 * (T + 1))).all(), got
    assert len(set(got.tolist())) == len(got), got


def test_fused_interpret_kernel_bit_identical_to_ref_apply():
    """The Pallas kernel route (interpret mode on CPU) produces bit-identical
    sampler states to the jnp-apply route: the statistical guarantees checked
    on the default route extend to the kernel path verbatim."""
    batch_sizes = [10, 3, 0, 14, 8, 5]
    batches, bcounts = _id_stream(batch_sizes, max(batch_sizes))
    n, lam = 12, 0.25
    st_i = st_r = rtbs.init(PROTO, n)
    for t in range(len(batch_sizes)):
        kt = jax.random.fold_in(jax.random.key(7), t)
        bt = batches[t]
        st_i = rtbs.step(kt, st_i, bt, bcounts[t], n=n, lam=lam,
                         impl="interpret")
        st_r = rtbs.step(kt, st_r, bt, bcounts[t], n=n, lam=lam, impl="ref")
        for a, b in zip(jax.tree_util.tree_leaves(st_i),
                        jax.tree_util.tree_leaves(st_r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_step_theorem_4_2():
    """Theorem 4.2 re-run against the FUSED path: Pr[i in S_t] ==
    (C_t/W_t) w_t(i) for every batch age (the fuller grids live in
    tests/test_core_sampling.py, which drives the same fused default)."""
    batch_sizes, lam, n = [4, 4, 4, 4, 4, 4, 4, 4], 0.3, 8
    T = len(batch_sizes)
    batches, bcounts = _id_stream(batch_sizes, max(batch_sizes))

    def one(kk):
        st = rtbs.init(PROTO, n)
        k_run, k_real = jax.random.split(kk)
        st, _ = rtbs.run_stream(k_run, st, batches, bcounts, n=n, lam=lam)
        mask, _ = lt.realize(k_real, st.lat)
        batch_of = st.lat.items // 1000
        counts = jnp.zeros((T + 1,), jnp.float32).at[batch_of].add(
            mask.astype(jnp.float32)
        )
        return counts[1:]

    counts = jax.vmap(one)(keys(0, 12000))
    probs = np.asarray(counts.mean(axis=0)) / 4
    w = 0.0
    ws = []
    for b in batch_sizes:
        w = math.exp(-lam) * w + b
        ws.append(w)
    C_T, W_T = min(n, ws[-1]), ws[-1]
    for j in range(T):
        expect = (C_T / W_T) * math.exp(-lam * (T - 1 - j))
        assert abs(probs[j] - expect) < 0.025, (j, probs[j], expect)


def test_fused_downsample_theorem_4_1():
    """Theorem 4.1 against the argsort-free downsample map (the grid version
    lives in tests/test_core_sampling.py)."""
    c, cp, cap = 5.6, 3.2, 10
    k = math.floor(c)
    ids = jnp.arange(cap, dtype=jnp.int32)
    base = lt.Latent(items=ids, nfull=jnp.int32(k), weight=jnp.float32(c))

    def one(kk):
        k1, k2 = jax.random.split(kk)
        out = lt.downsample(k1, base, jnp.float32(cp))
        mask, _ = lt.realize(k2, out)
        member = jnp.zeros((cap,), jnp.float32)
        return member.at[out.items].add(mask.astype(jnp.float32))

    member = np.asarray(jax.vmap(one)(keys(1, 20000)).mean(axis=0))
    scale = cp / c
    for i in range(k):
        assert abs(member[i] - scale) < 0.02, (i, member[i], scale)
    assert abs(member[k] - scale * (c - k)) < 0.02


# ---------------------------------------------------------------------------
# 4. superbatched manage loop: bit-identical for any chunk size
# ---------------------------------------------------------------------------
def test_superbatch_bit_identical():
    from repro.data.streams import LinRegStream
    from repro.manage import make_model, make_run_loop, materialize_stream
    from repro.manage.loop import _effective_superbatch

    assert _effective_superbatch(None, 1) == 1
    assert _effective_superbatch(8, 4) == 4
    assert _effective_superbatch(8, 12) == 6      # largest divisor <= 8
    assert _effective_superbatch(3, 4) == 2
    assert _effective_superbatch(5, 7) == 1

    sampler = make_sampler("rtbs", n=40, lam=0.15)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(LinRegStream(seed=0), 11,
                                          batch_size=16)
    key = jax.random.key(3)
    outs = []
    for sb in (1, 2, 4):   # T=11, retrain_every=4: chunked scan + tail ticks
        st, params, trace = make_run_loop(
            sampler, model, retrain_every=4, superbatch=sb
        )(key, batches, bcounts)
        outs.append((st, params, trace))
    for st, params, trace in outs[1:]:
        for a, b in zip(jax.tree_util.tree_leaves((st, params, trace)),
                        jax.tree_util.tree_leaves(outs[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 5. sample materialization via reservoir_compact
# ---------------------------------------------------------------------------
def test_materialize_view_packs_scattered_mask():
    cap = 21
    items = {"x": jnp.arange(cap * 2, dtype=jnp.float32).reshape(cap, 2),
             "y": jnp.arange(cap, dtype=jnp.int32)}
    mask = jnp.asarray(np.arange(cap) % 3 == 1)   # scattered membership
    size = jnp.int32(int(np.asarray(mask).sum()))
    dense = materialize_view(SampleView(items=items, mask=mask, size=size))
    assert int(dense.mask.sum()) == int(size)
    assert bool(dense.mask[: int(size)].all())
    got = np.asarray(dense.items["y"][: int(size)])
    np.testing.assert_array_equal(got, np.arange(cap)[np.asarray(mask)])
    np.testing.assert_array_equal(
        np.asarray(dense.items["x"][: int(size)]),
        np.asarray(items["x"])[np.asarray(mask)],
    )


def test_latent_realize_compact_matches_realize():
    cap = 9
    lat = lt.Latent(items=jnp.arange(cap, dtype=jnp.int32) + 1,
                    nfull=jnp.int32(5), weight=jnp.float32(5.7))
    for s in range(8):
        kk = jax.random.key(s)
        mask, size = lt.realize(kk, lat)
        packed, size2 = lt.realize_compact(kk, lat)
        assert int(size) == int(size2)   # same key -> same partial draw
        np.testing.assert_array_equal(
            np.asarray(packed[: int(size)]),
            np.asarray(lat.items)[np.asarray(mask)],
        )
