"""repro.obs contracts (DESIGN.md Sec. 14):

  * telemetry-off and telemetry-on loops produce bit-identical outputs
    (state, params, trace) on the local, bank, and sharded paths;
  * the instrumented fast-tick path adds ZERO device-to-host transfers --
    the whole run executes under a transfer guard, on both drain
    transports (fetch: rows leave as jit outputs, pulled by the wrapper
    under its own allow scope; callback: the boundary drains ride a
    token-chained ``pure_callback``, which the guard does not count);
  * drained records are complete and ordered, the bank's probe columns
    satisfy the Thm 4.1 weight recursion on the host, and the health
    monitors fire on the failure shapes they exist for;
  * sinks round-trip records (JSONL / stdout / memory ring);
  * the measured telemetry overhead (BENCH_obs_overhead.json) stays within
    the <= 5% acceptance bound.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bank import make_bank
from repro.core.api import make_sampler
from repro.data.streams import KeyedStream, LinRegStream
from repro.decay import loss_ratio
from repro.manage import (
    make_bank_run_loop,
    make_model,
    make_run_loop,
    materialize_stream,
)
from repro.obs import (
    InclusionDrift,
    JsonlSink,
    MemorySink,
    NanAlarm,
    OverflowAlarm,
    SampleSizeStability,
    StdoutSink,
    StuckLambda,
    Telemetry,
    default_monitors,
    tree_nbytes,
)

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (pa, xa), (_, xb) in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                      err_msg=str(pa))


def _linreg_run(T=23, b=20):
    batches, bcounts = materialize_stream(LinRegStream(seed=0), T,
                                          batch_size=b)
    return batches, bcounts


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------
def test_jsonl_sink_roundtrip(tmp_path):
    path = tmp_path / "sub" / "telemetry.jsonl"
    s = JsonlSink(str(path))
    s.emit({"kind": "tick", "t": 0, "metric": jnp.float32(1.5),
            "size": np.int32(7), "vec": np.arange(3)})
    s.emit({"kind": "warning", "monitor": "nan", "message": "boom"})
    s.close()
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    assert recs[0] == {"kind": "tick", "t": 0, "metric": 1.5, "size": 7,
                       "vec": [0, 1, 2]}
    assert recs[1]["monitor"] == "nan"
    # append mode: a reopened sink extends the stream
    s2 = JsonlSink(str(path))
    s2.emit({"kind": "tick", "t": 1})
    s2.close()
    assert len(path.read_text().splitlines()) == 3


def test_memory_sink_ring_and_filter():
    s = MemorySink(capacity=3)
    for t in range(5):
        s.emit({"kind": "tick", "t": t})
    s.emit({"kind": "warning", "monitor": "m", "message": "x"})
    assert [r["t"] for r in s.by_kind("tick")] == [3, 4]  # ring evicted 0-2
    assert len(s.by_kind("warning")) == 1


def test_stdout_sink_kind_filter(capsys):
    s = StdoutSink(kinds=("warning",))
    s.emit({"kind": "tick", "t": 0})
    s.emit({"kind": "warning", "monitor": "m", "message": "x"})
    s.flush()
    out = capsys.readouterr().out
    assert "warning" in out and "tick" not in out


# ---------------------------------------------------------------------------
# monitors
# ---------------------------------------------------------------------------
def test_nan_alarm_fires_on_nonfinite_metric():
    m = NanAlarm()
    assert m.observe({"kind": "tick", "t": 0, "metric": 1.0, "bcount": 4}) == []
    ws = m.observe({"kind": "tick", "t": 1, "metric": float("nan"),
                    "bcount": 4})
    assert ws and ws[0]["kind"] == "warning" and ws[0]["monitor"] == m.name


def test_overflow_alarm_fires_and_cools_down():
    m = OverflowAlarm(cooldown=2)
    ws = m.observe({"kind": "tick", "t": 0, "overflow": 3})
    assert len(ws) == 1 and "3" in str(ws[0])
    assert m.observe({"kind": "tick", "t": 1, "overflow": 5}) == []  # cooling
    assert m.observe({"kind": "tick", "t": 2, "overflow": 5}) == []
    assert len(m.observe({"kind": "tick", "t": 3, "overflow": 1})) == 1


def test_stuck_lambda_fires_after_patience():
    m = StuckLambda(patience=3, lam_max=0.5)
    ws = []
    for t in range(8):
        ws += m.observe({"kind": "tick", "t": t, "lam": 0.5 if t else 0.1,
                         "pulse": False})
    assert any(w["monitor"] == m.name for w in ws)


def test_inclusion_drift_detects_broken_recursion():
    m = InclusionDrift(rtol=0.05, warmup=2)
    w = 0.0
    ws = []
    for t in range(10):
        w = 0.9 * w + 16.0
        ws += m.observe({"kind": "tick", "t": t, "decay": 0.9, "bcount": 16,
                         "total_weight": w})
    assert ws == []  # exact recursion: silent
    # now corrupt the reported weight
    ws = m.observe({"kind": "tick", "t": 10, "decay": 0.9, "bcount": 16,
                    "total_weight": 2.0 * w})
    assert ws and ws[0]["monitor"] == m.name


def test_sample_size_stability_flags_collapse():
    m = SampleSizeStability(window=8, rtol=0.2, atol=1.0)
    ws = []
    for t in range(16):
        ws += m.observe({"kind": "tick", "t": t, "size": 50, "weight": 50.0})
    assert ws == []
    for t in range(16, 32):  # |S| collapses while C stays at 50
        ws += m.observe({"kind": "tick", "t": t, "size": 5, "weight": 50.0})
    assert any(w["monitor"] == m.name for w in ws)


# ---------------------------------------------------------------------------
# the instrumented local loop
# ---------------------------------------------------------------------------
def test_run_loop_telemetry_bit_identity_and_records():
    sampler = make_sampler("rtbs", n=50, lam=0.1)
    model = make_model("linreg", dim=2)
    batches, bcounts = _linreg_run()
    mem = MemorySink()
    tel = Telemetry([mem], every=6, monitors=default_monitors())
    off = make_run_loop(sampler, model, retrain_every=4, superbatch=2)
    on = make_run_loop(sampler, model, retrain_every=4, superbatch=2,
                       telemetry=tel)
    assert on is make_run_loop(sampler, model, retrain_every=4, superbatch=2,
                               telemetry=tel)  # memoized per handle
    key = jax.random.key(7)
    _assert_trees_equal(off(key, batches, bcounts), on(key, batches, bcounts))

    runs = mem.by_kind("run")
    ticks = mem.by_kind("tick")
    assert len(runs) == 1 and runs[0]["scheme"] == "rtbs"
    assert runs[0]["ticks"] == 23 and runs[0]["superbatch"] == 2
    assert runs[0]["state_bytes"] > 0
    assert [r["t"] for r in ticks] == list(range(23))  # ordered, complete
    for col in ("bcount", "metric", "size", "retrain", "weight",
                "total_weight", "fill_frac", "decay"):
        assert col in ticks[0], col
    assert ticks[0]["retrain"] is False and ticks[3]["retrain"] is True
    assert mem.by_kind("warning") == []  # healthy run
    # Thm 4.1 recursion from the drained columns: W_t = d W_{t-1} + |B_t|
    w = 0.0
    for r in ticks:
        w = r["decay"] * w + r["bcount"]
        np.testing.assert_allclose(w, r["total_weight"], rtol=1e-4)
    # a second invocation opens a new run and re-drains
    on(key, batches, bcounts)
    assert len(mem.by_kind("run")) == 2
    assert len(mem.by_kind("tick")) == 46


def test_run_loop_telemetry_transports_equivalent():
    """The two drain transports (fetch: rows as jit outputs, drained after
    the run; callback: in-scan token-chained pure_callback) yield
    bit-identical loop outputs and the same tick-record stream."""
    sampler = make_sampler("rtbs", n=50, lam=0.1)
    model = make_model("linreg", dim=2)
    batches, bcounts = _linreg_run()
    key = jax.random.key(7)
    outs, ticks = [], []
    for transport in ("fetch", "callback"):
        mem = MemorySink()
        tel = Telemetry([mem], every=6, monitors=(), transport=transport)
        on = make_run_loop(sampler, model, retrain_every=4, superbatch=2,
                           telemetry=tel)
        outs.append(on(key, batches, bcounts))
        ticks.append(mem.by_kind("tick"))
    _assert_trees_equal(outs[0], outs[1])
    assert ticks[0] == ticks[1]  # same records, same order


def test_run_loop_telemetry_zero_host_transfers():
    """The instrumented scan must not add device->host transfers: the whole
    run executes under a disallow guard. Covers BOTH drain transports --
    "fetch" (rows ride out as jit outputs; the wrapper's drain fetch opts
    into its own inner allow scope) and "callback" (drains ride the
    token-chained pure_callback, which the guard does not count)."""
    sampler = make_sampler("rtbs", n=30, lam=0.1)
    model = make_model("linreg", dim=2)
    batches, bcounts = _linreg_run(T=16, b=12)
    key = jax.random.key(0)
    for transport in ("fetch", "callback"):
        tel = Telemetry([MemorySink()], every=4, monitors=default_monitors(),
                        transport=transport)
        on = make_run_loop(sampler, model, retrain_every=4, superbatch=4,
                           telemetry=tel)
        on(key, batches, bcounts)  # compile outside the guard
        with jax.transfer_guard_device_to_host("disallow"):
            out = on(key, batches, bcounts)
        assert np.isfinite(np.asarray(out[2]["metric"])[1:]).all()
        assert tel.ticks == 32, transport


def test_run_loop_telemetry_controller_gauges():
    sampler = make_sampler("rtbs", n=40, lam=0.1)
    model = make_model("linreg", dim=2)
    ctrl = loss_ratio(lam0=0.1, lam_min=0.02, lam_max=0.8)
    batches, bcounts = _linreg_run(T=12, b=16)
    mem = MemorySink()
    tel = Telemetry([mem], every=4, monitors=default_monitors(lam_max=0.8))
    off = make_run_loop(sampler, model, retrain_every=3, controller=ctrl)
    on = make_run_loop(sampler, model, retrain_every=3, controller=ctrl,
                       telemetry=tel)
    key = jax.random.key(3)
    _assert_trees_equal(off(key, batches, bcounts), on(key, batches, bcounts))
    t0 = mem.by_kind("tick")[0]
    assert {"lam", "hold", "pulse", "decay"} <= set(t0)


# ---------------------------------------------------------------------------
# the instrumented sharded loop (1-shard mesh; the 8-device run rides the
# subprocess checks in test_sharded_loop.py)
# ---------------------------------------------------------------------------
def test_sharded_loop_telemetry_bit_identity_and_records():
    from repro.launch.mesh import make_data_mesh
    from repro.manage import make_sharded_run_loop, shard_stream

    T = 12
    sampler = make_sampler("drtbs", n=24, lam=0.2, cap_s=64)
    model = make_model("linreg", dim=2)
    batches, bcounts = materialize_stream(LinRegStream(seed=0), T,
                                          batch_size=16)
    batches, bcounts = shard_stream(batches, bcounts, 1)
    mesh = make_data_mesh(1)
    key = jax.random.key(2)
    off = make_sharded_run_loop(sampler, model, mesh, retrain_every=2,
                                superbatch=2)
    out_off = off(key, batches, bcounts)
    for transport in ("fetch", "callback"):
        mem = MemorySink()
        tel = Telemetry([mem], every=4, monitors=default_monitors(),
                        transport=transport)
        on = make_sharded_run_loop(sampler, model, mesh, retrain_every=2,
                                   superbatch=2, telemetry=tel)
        _assert_trees_equal(out_off, on(key, batches, bcounts))
        ticks = mem.by_kind("tick")
        assert [r["t"] for r in ticks] == list(range(T)), transport
        assert len(mem.by_kind("run")) == 1


# ---------------------------------------------------------------------------
# the instrumented bank loop
# ---------------------------------------------------------------------------
def _keyed(K=16, T=14, b=24):
    stream = KeyedStream(base=LinRegStream(seed=0), num_keys=K, alpha=1.2,
                         flip_every=6)
    return materialize_stream(stream, T, batch_size=b,
                              fields=("key", "x", "y"))


def test_bank_loop_overflow_in_trace_and_telemetry_bit_identity():
    K, Q, T = 16, 4, 14
    batches, bcounts = _keyed(K=K, T=T)
    # bcap=2 forces routing drops so the overflow column is exercised
    bank = make_bank("rtbs", num_keys=K, n=8, lam=0.1, bcap=2)
    model = make_model("linreg", dim=2)
    off = make_bank_run_loop(bank, model, retrain_every=4,
                             train_keys=range(Q), superbatch=2)
    mem = MemorySink()
    tel = Telemetry([mem], every=4, monitors=default_monitors(),
                    probe_key=1)
    on = make_bank_run_loop(bank, model, retrain_every=4,
                            train_keys=range(Q), superbatch=2, telemetry=tel)
    key = jax.random.key(5)
    out_off = off(key, batches, bcounts)
    out_on = on(key, batches, bcounts)
    _assert_trees_equal(out_off, out_on)

    # satellite: per-tick dropped-item counts surface in the metrics trace
    # (telemetry on or off) and reconcile with the state's cumulative count
    ov = np.asarray(out_off[2]["overflow"])
    assert ov.shape == (T,) and ov.sum() > 0
    assert ov.sum() == int(np.asarray(out_off[0].overflow).sum())

    ticks = mem.by_kind("tick")
    assert [r["t"] for r in ticks] == list(range(T))
    for col in ("overflow", "ntouched", "invalid", "decay", "pending_min",
                "probe_key", "probe_arrivals", "probe_total_weight",
                "probe_weight", "probe_overflow"):
        assert col in ticks[0], col
    assert all(r["probe_key"] == 1 for r in ticks)
    # the probed tenant's Thm 4.1 recursion holds on the host
    w = 0.0
    for r in ticks:
        w = r["decay"] * w + r["probe_arrivals"]
        np.testing.assert_allclose(w, r["probe_total_weight"], rtol=1e-3,
                                   atol=1e-4)
    # the forced drops fire the overflow alarm through the sinks
    assert any(w_["monitor"] == "overflow_alarm"
               for w_ in mem.by_kind("warning"))


def test_bank_step_stats_matches_step():
    bank = make_bank("rtbs", num_keys=8, n=6, lam=0.2, bcap=4)
    proto = jax.ShapeDtypeStruct((), jnp.float32)
    state = bank.init(proto)
    rng = np.random.default_rng(1)
    key = jax.random.key(0)
    plain, stats_state = state, state
    for t in range(5):
        kt = jax.random.fold_in(key, t)
        keys_t = jnp.asarray(rng.integers(0, 8, (12,)), jnp.int32)
        payload = jnp.asarray(rng.normal(size=(12,)), jnp.float32)
        plain = bank.step(kt, plain, keys_t, payload, jnp.int32(10))
        stats_state, st = bank.step_stats(kt, stats_state, keys_t, payload,
                                          jnp.int32(10))
        assert {"overflow", "ntouched", "invalid", "decay"} <= set(st)
        assert int(st["ntouched"]) >= 1
    _assert_trees_equal(plain, stats_state)


# ---------------------------------------------------------------------------
# probes / misc
# ---------------------------------------------------------------------------
def test_tree_nbytes():
    tree = {"a": jnp.zeros((4, 2), jnp.float32), "b": jnp.zeros((3,), jnp.int32)}
    assert tree_nbytes(tree) == 4 * 2 * 4 + 3 * 4


def test_telemetry_every_validation():
    with pytest.raises(ValueError):
        Telemetry([MemorySink()], every=0)


def test_bench_obs_overhead_within_bound():
    """The committed overhead benchmark must show telemetry-on within the
    <= 5% acceptance bound on the manage-loop criterion row (full mode
    only; smoke json is CI-sized and not a perf claim)."""
    path = REPO_ROOT / "BENCH_obs_overhead.json"
    if not path.exists():
        pytest.skip("BENCH_obs_overhead.json not generated yet")
    payload = json.loads(path.read_text())
    if payload.get("smoke"):
        pytest.skip("smoke-mode bench json carries no perf claim")
    rows = {r["name"]: r for r in payload["rows"]}
    on = [r for n, r in rows.items() if "manage" in n and "_on" in n]
    assert on and all(r["overhead_pct"] <= 5.0 for r in on), on
