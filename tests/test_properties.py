"""Hypothesis property-based tests on the system's invariants.

Shapes are FIXED per test (one jit compile); hypothesis drives the *values*
(batch-size sequences, decay rates, masks), so hundreds of examples run in
seconds on one core.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import latent as lt
from repro.core import ref, rng, rtbs
from repro.data.streams import TokenDriftStream

PROTO = jax.ShapeDtypeStruct((), jnp.int32)
N = 8
BCAP = 16
T = 10

_step = jax.jit(
    lambda key, st_, items, cnt, lam: rtbs.step(
        key, st_, items, cnt, n=N, lam=lam
    )
)


def _run(batch_sizes, lam, seed=0):
    st_ = rtbs.init(PROTO, N)
    items = jnp.arange(BCAP, dtype=jnp.int32)
    cs, ws = [], []
    for t, b in enumerate(batch_sizes):
        st_ = _step(
            jax.random.fold_in(jax.random.key(seed), t),
            st_, items + 100 * t, jnp.int32(b), jnp.float32(lam),
        )
        cs.append(float(st_.lat.weight))
        ws.append(float(st_.total_weight))
    return st_, cs, ws


@settings(max_examples=40, deadline=None)
@given(
    batch_sizes=st.lists(st.integers(0, BCAP), min_size=1, max_size=T),
    lam=st.floats(0.01, 1.5),
    seed=st.integers(0, 1000),
)
def test_rtbs_bookkeeping_invariants(batch_sizes, lam, seed):
    """For ANY batch-size sequence and decay rate:
      (a) C_t == min(n, W_t) to float tolerance,
      (b) W_t follows the decay recurrence exactly,
      (c) floor(C_t) == stored full count, and the realized size is <= n."""
    st_, cs, ws = _run(batch_sizes, lam, seed)
    w = 0.0
    for t, b in enumerate(batch_sizes):
        w = math.exp(-lam) * w + b
        assert abs(ws[t] - w) < 1e-3 * max(1.0, w) + 1e-4
        assert abs(cs[t] - min(N, w)) < 1e-3 * max(1.0, w) + 1e-4
    assert int(st_.lat.nfull) == int(np.floor(cs[-1] + 1e-4))
    mask, size = rtbs.realize(jax.random.key(seed + 1), st_)
    assert int(size) <= N
    assert int(np.asarray(mask).sum()) == int(size)


@settings(max_examples=40, deadline=None)
@given(
    batch_sizes=st.lists(st.integers(0, BCAP), min_size=2, max_size=T),
    lam=st.floats(0.05, 1.0),
    seed=st.integers(0, 1000),
)
def test_rtbs_trajectories_match_paper_literal_ref(batch_sizes, lam, seed):
    """The deterministic scalars of the JAX impl and the paper-literal Python
    reference agree on any stream."""
    _, cs, ws = _run(batch_sizes, lam, seed)
    r = ref.RefRTBS(n=N, lam=lam, seed=seed)
    for t, b in enumerate(batch_sizes):
        r.step(list(range(b)))
        assert abs(r.W - ws[t]) < 1e-3 * max(1.0, r.W) + 1e-4
        assert abs(r.lat.weight - cs[t]) < 1e-3 * max(1.0, r.lat.weight) + 1e-4


_ds = jax.jit(lambda key, lat_, c2: lt.downsample(key, lat_, c2))


@settings(max_examples=60, deadline=None)
@given(
    c=st.floats(0.5, 9.9),
    frac_to=st.floats(0.05, 1.0),
    seed=st.integers(0, 10_000),
)
def test_downsample_weight_algebra(c, frac_to, seed):
    """downsample(C -> C') always produces weight C', floor(C') full items,
    and only items that existed before (no fabrication)."""
    cap = 11
    k = math.floor(c)
    ids = jnp.arange(cap, dtype=jnp.int32) + 1
    lat = lt.Latent(items=ids, nfull=jnp.int32(k), weight=jnp.float32(c))
    c2 = max(min(c * frac_to, c), 1e-3)
    out = _ds(jax.random.key(seed), lat, jnp.float32(c2))
    assert abs(float(out.weight) - min(c2, c)) < 1e-5
    assert int(out.nfull) == math.floor(min(c2, c))
    live = int(out.nfull) + (1 if (min(c2, c) % 1.0) > 0 else 0)
    valid_src = set(np.asarray(ids)[: k + (1 if c % 1.0 > 0 else 0)].tolist())
    got = np.asarray(out.items)[:live].tolist()
    assert set(got) <= valid_src
    assert len(set(got)) == len(got)  # no duplicates among live slots


@settings(max_examples=50, deadline=None)
@given(
    k=st.integers(0, 20),
    a=st.integers(0, 20),
    b=st.integers(0, 20),
    seed=st.integers(0, 10_000),
)
def test_hypergeometric_support(k, a, b, seed):
    """Draws always land in [max(0, k-b), min(a, k)] (valid k only)."""
    k = min(k, a + b)
    x = int(rng.hypergeometric(jax.random.key(seed), k, a, b, max_support=64))
    assert max(0, k - b) <= x <= min(a, k)


@settings(max_examples=30, deadline=None)
@given(
    total=st.integers(0, 30),
    counts=st.lists(st.integers(0, 10), min_size=2, max_size=6),
    seed=st.integers(0, 10_000),
)
def test_mvhg_partition(total, counts, seed):
    """Multivariate-hypergeometric splits are exact partitions within bounds."""
    csum = sum(counts)
    total = min(total, csum)
    xs = rng.multivariate_hypergeometric(
        jax.random.key(seed), total, jnp.asarray(counts, jnp.int32),
        max_support=16,
    )
    xs = np.asarray(xs)
    assert xs.sum() == total
    assert (xs >= 0).all() and (xs <= np.asarray(counts)).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), mode=st.integers(0, 1))
def test_stream_determinism(seed, mode):
    """Streams are pure functions of (seed, t, mode): the replay contract
    behind bit-exact checkpoint restarts."""
    s1 = TokenDriftStream(seed=seed).batch(3, 8, mode)
    s2 = TokenDriftStream(seed=seed).batch(3, 8, mode)
    np.testing.assert_array_equal(s1, s2)
